//! Multi-threaded load generators for the concurrent serving surface.
//!
//! Closed loop ([`run_closed_loop`]): each worker owns a session and drives
//! the blocking `submit` path (next request issues as soon as the previous
//! one returns), submitting a seeded mixed-sensitivity workload and nudging
//! the virtual clock so the Sim fleet's slots keep clearing. Used by
//! `benches/throughput.rs`, `benches/failover.rs` and the stress tests;
//! returns the per-request outcomes so callers can cross-check ids, audit
//! entries and ledger totals.
//!
//! Open loop ([`run_open_loop`]): producers drive the non-blocking
//! `enqueue` path — the whole arrival stream is pushed without waiting for
//! completions (arrivals are independent of service times, the
//! backpressure regime the admission queue exists for), then every
//! [`Ticket`] is awaited. Used by `benches/queue_latency.rs`, the
//! `loadgen` CLI command and the queue stress test.
//!
//! Churn mode ([`run_closed_loop_churn`]) adds a driver thread that
//! crashes/revives/leaves/rejoins islands *while the workers submit*: a mix
//! of announced crashes (the liveness view learns immediately) and silent
//! ones (detected only by heartbeat timeout or a failed execution, which
//! exercises the orchestrator's failover path).
//!
//! Socket mode ([`run_open_loop_http`]): the same open-loop arrival
//! schedule (identical class mix, prompts and seeding) driven through a
//! real [`crate::server::HttpServer`] endpoint over loopback TCP — submit
//! over keep-alive connections, then poll every ticket to its terminal
//! resolution. In-process vs. socket overhead is directly comparable
//! because only the transport differs.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::json::Json;
use crate::server::http::client::HttpClient;
use crate::server::http::wire::priority_name;
use crate::server::{Orchestrator, Outcome, SubmitRequest, Ticket};
use crate::substrate::trace::{priority_for, prompt_for, SensClass};
use crate::telemetry::{format_traceparent, SpanId, TraceId};
use crate::types::Island;
use crate::util::Rng;

use crate::util::sync::LockExt;

/// Aggregate result of one closed-loop run.
#[derive(Debug)]
pub struct LoadReport {
    pub threads: usize,
    /// Requests attempted (threads × per_thread).
    pub attempted: usize,
    /// Outcomes of admitted requests (served or fail-closed rejections).
    pub outcomes: Vec<Outcome>,
    /// Submissions refused before routing (rate limit / session errors).
    pub errors: usize,
    pub wall_s: f64,
}

impl LoadReport {
    pub fn served(&self) -> usize {
        self.outcomes.iter().filter(|o| o.decision.target().is_some()).count()
    }

    pub fn rejected(&self) -> usize {
        self.outcomes.len() - self.served()
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.attempted as f64 / self.wall_s
        }
    }
}

/// The canonical generator workload mix, by request index: 25% high /
/// 50% moderate / 25% low sensitivity. Shared by both loop drivers here and
/// by the queue stress test and queue-latency bench, so the mix is tuned in
/// exactly one place.
pub fn class_for(i: usize) -> SensClass {
    match i % 4 {
        0 => SensClass::High,
        1 | 2 => SensClass::Moderate,
        _ => SensClass::Low,
    }
}

/// Turns per conversation before a worker opens a fresh session. Keeps the
/// workload realistic (short chats) and bounds the per-submit history that
/// MIST re-analyzes — one endless session would make the closed loop
/// quadratic in requests.
const SESSION_TURNS: usize = 8;

/// Island-churn program driven alongside the closed loop: per step, each
/// online island crashes with `crash_prob` and each crashed island revives
/// with `revive_prob`; occasionally an island leaves the mesh entirely and
/// rejoins later. Rates are per churn step (`step_ms` wall-clock apart).
#[derive(Clone, Copy, Debug)]
pub struct Churn {
    pub crash_prob: f64,
    pub revive_prob: f64,
    /// Probability an online island *leaves* the mesh for a while.
    pub leave_prob: f64,
    /// Wall-clock milliseconds between churn steps.
    pub step_ms: u64,
    /// Fraction of crashes that are announced (liveness view learns
    /// immediately); the rest are silent and must be *detected*.
    pub announced_fraction: f64,
}

impl Default for Churn {
    fn default() -> Self {
        Churn { crash_prob: 0.25, revive_prob: 0.6, leave_prob: 0.05, step_ms: 2, announced_fraction: 0.5 }
    }
}

/// What the churn driver did during a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChurnStats {
    pub crashes: u64,
    pub revives: u64,
    pub leaves: u64,
    pub joins: u64,
}

/// Drive `threads` workers × `per_thread` closed-loop submissions through a
/// shared orchestrator. Deterministic prompt streams per (seed, worker).
pub fn run_closed_loop(orch: &Arc<Orchestrator>, threads: usize, per_thread: usize, seed: u64) -> LoadReport {
    run_closed_loop_churn(orch, threads, per_thread, seed, None).0
}

/// Closed-loop run with an optional churn program. The fleet is restored
/// (every island revived / rejoined) before the report is returned, so
/// callers can run repeated phases against one orchestrator.
pub fn run_closed_loop_churn(
    orch: &Arc<Orchestrator>,
    threads: usize,
    per_thread: usize,
    seed: u64,
    churn: Option<Churn>,
) -> (LoadReport, ChurnStats) {
    let outcomes = Arc::new(Mutex::new(Vec::with_capacity(threads * per_thread)));
    let errors = Arc::new(Mutex::new(0usize));
    let done = Arc::new(AtomicBool::new(false));
    let t0 = std::time::Instant::now();
    let churn_handle = churn.map(|plan| {
        let orch = Arc::clone(orch);
        let done = Arc::clone(&done);
        std::thread::spawn(move || drive_churn(&orch, plan, seed, &done))
    });
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let orch = Arc::clone(orch);
            let outcomes = Arc::clone(&outcomes);
            let errors = Arc::clone(&errors);
            std::thread::spawn(move || {
                let user = format!("loadgen-{t}");
                let mut session = orch.open_session(&user);
                let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut local = Vec::with_capacity(per_thread);
                let mut local_errors = 0usize;
                for i in 0..per_thread {
                    if i > 0 && i % SESSION_TURNS == 0 {
                        session = orch.open_session(&user);
                    }
                    let class = class_for(i);
                    let prompt = prompt_for(class, &mut rng);
                    match orch.submit_request(session, SubmitRequest::new(&prompt).priority(priority_for(class))) {
                        Ok(out) => local.push(out),
                        Err(_) => local_errors += 1,
                    }
                    // keep virtual time moving so slots clear and token
                    // buckets refill; atomic, so safe from every worker
                    orch.advance(5.0);
                }
                outcomes.lock_clean().extend(local);
                *errors.lock_clean() += local_errors;
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    done.store(true, Ordering::SeqCst);
    let churn_stats = churn_handle.map(|h| h.join().unwrap()).unwrap_or_default();
    let wall_s = t0.elapsed().as_secs_f64();
    let outcomes = Arc::try_unwrap(outcomes).expect("workers joined").into_inner().unwrap();
    let errors = *errors.lock_clean();
    (LoadReport { threads, attempted: threads * per_thread, outcomes, errors, wall_s }, churn_stats)
}

/// The churn driver loop: mutates fleet membership until `done`, then
/// restores every island so the orchestrator is reusable. Observes the
/// fleet only through the orchestrator's narrow island accessors.
fn drive_churn(orch: &Arc<Orchestrator>, plan: Churn, seed: u64, done: &AtomicBool) -> ChurnStats {
    let mut stats = ChurnStats::default();
    if !orch.sim_backed() {
        return stats; // churn scaffolding only exists on the simulator
    }
    let mut rng = Rng::new(seed ^ 0xC4_52_11);
    let mut parked: Vec<Island> = Vec::new();
    let ids = orch.island_ids();
    while !done.load(Ordering::SeqCst) {
        for &id in &ids {
            // liveness-only probe: this loop runs hot alongside the
            // serving path, so it must not clone specs per step
            let Some(online) = orch.island_online(id) else {
                // currently left the mesh: maybe rejoin
                if rng.f64() < plan.revive_prob {
                    if let Some(pos) = parked.iter().position(|i| i.id == id) {
                        let spec = parked.swap_remove(pos);
                        if orch.join_island(spec) {
                            stats.joins += 1;
                        }
                    }
                }
                continue;
            };
            if online {
                if rng.f64() < plan.leave_prob {
                    if let Some(spec) = orch.leave_island(id) {
                        parked.push(spec);
                        stats.leaves += 1;
                    }
                } else if rng.f64() < plan.crash_prob {
                    let crashed = if rng.f64() < plan.announced_fraction {
                        orch.crash_island(id) // clean shutdown: liveness view told
                    } else {
                        orch.silent_crash_island(id) // silent death: must be detected
                    };
                    if crashed {
                        stats.crashes += 1;
                    }
                }
            } else if rng.f64() < plan.revive_prob && orch.revive_island(id) {
                stats.revives += 1;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(plan.step_ms));
    }
    // restore the fleet for subsequent phases
    for spec in parked {
        orch.join_island(spec);
    }
    for &id in &ids {
        if orch.island_online(id).is_some() {
            orch.revive_island(id);
        }
    }
    stats
}

/// Drive `producers` threads × `per_producer` arrivals through the
/// non-blocking [`Orchestrator::enqueue`] path: each producer pushes its
/// whole stream without waiting for completions (open loop — arrivals are
/// independent of service times), then awaits every [`Ticket`]. Starts the
/// worker pool if it is not running. Arrivals carry an effectively
/// unbounded deadline so the driver measures queue/serve behavior, not
/// deadline shedding (callers wanting sheds enqueue directly). The returned
/// [`LoadReport`] counts producers as `threads`; `outcomes` covers every
/// request that consumed an id (served, fail-closed rejects and queue
/// sheds alike) and `errors` counts tickets that resolved with an error.
pub fn run_open_loop(orch: &Arc<Orchestrator>, producers: usize, per_producer: usize, seed: u64) -> LoadReport {
    Arc::clone(orch).start_queue();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..producers)
        .map(|t| {
            let orch = Arc::clone(orch);
            std::thread::spawn(move || {
                let user = format!("openloop-{t}");
                let mut session = orch.open_session(&user);
                let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut tickets: Vec<Ticket> = Vec::with_capacity(per_producer);
                for i in 0..per_producer {
                    if i > 0 && i % SESSION_TURNS == 0 {
                        session = orch.open_session(&user);
                    }
                    let class = class_for(i);
                    let submit = SubmitRequest::new(prompt_for(class, &mut rng))
                        .priority(priority_for(class))
                        .deadline_ms(1e12);
                    tickets.push(orch.enqueue(session, submit));
                    // keep virtual time moving so slots clear and token
                    // buckets refill; atomic, so safe from every producer
                    orch.advance(5.0);
                }
                let mut outcomes = Vec::with_capacity(per_producer);
                let mut errors = 0usize;
                for ticket in tickets {
                    match ticket.wait() {
                        Ok(out) => outcomes.push(out),
                        Err(_) => errors += 1,
                    }
                }
                (outcomes, errors)
            })
        })
        .collect();
    let mut outcomes = Vec::with_capacity(producers * per_producer);
    let mut errors = 0usize;
    for h in handles {
        let (outs, errs) = h.join().unwrap();
        outcomes.extend(outs);
        errors += errs;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    LoadReport { threads: producers, attempted: producers * per_producer, outcomes, errors, wall_s }
}

/// Aggregate result of one socket-mode open-loop run. Unlike [`LoadReport`]
/// the outcomes live server-side; the client only observes the typed
/// resolution class off the wire, so the report carries counts, not
/// [`Outcome`]s.
#[derive(Debug)]
pub struct HttpLoadReport {
    /// Keep-alive connections driven (one per producer).
    pub connections: usize,
    /// Requests attempted (connections × per_connection).
    pub attempted: usize,
    /// Tickets that resolved `served` (a routing decision with a target).
    pub served: usize,
    /// Tickets that resolved with any other typed class (shed / failed /
    /// cancelled) — fail-closed rejections, counted not lost.
    pub rejected: usize,
    /// Transport or protocol errors: refused submits (401/429/400), ticket
    /// polls that 404ed, or tickets whose terminal state was an error.
    pub errors: usize,
    /// Hex trace ids the server returned for admitted submits. Producers
    /// send a distinct W3C `traceparent` per request, so these are the
    /// client-minted ids echoed back — the cross-system correlation handle.
    pub trace_ids: Vec<String>,
    pub wall_s: f64,
}

impl HttpLoadReport {
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.attempted as f64 / self.wall_s
        }
    }
}

/// How long [`run_open_loop_http`] will poll one ticket before giving up
/// and counting it as an error — a liveness backstop, never hit when the
/// server is healthy.
const HTTP_POLL_DEADLINE: Duration = Duration::from_secs(120);

/// Drive `producers` keep-alive connections × `per_producer` arrivals
/// through a live [`crate::server::HttpServer`] at `addr`: the socket-true
/// twin of [`run_open_loop`]. Each producer submits its whole stream over
/// `POST /v1/submit` without waiting for completions (same class mix,
/// prompts, per-producer seeding and unbounded deadline as the in-process
/// driver, so the two measure the same workload and differ only in
/// transport), then polls every ticket over `GET /v1/tickets/:id` to its
/// terminal resolution. Producer `t` authenticates with
/// `api_keys[t % len]`; virtual time is the server's concern (its clock
/// pump), so no `advance` calls happen here.
pub fn run_open_loop_http(
    addr: SocketAddr,
    api_keys: &[String],
    producers: usize,
    per_producer: usize,
    seed: u64,
) -> HttpLoadReport {
    assert!(!api_keys.is_empty(), "run_open_loop_http needs at least one API key");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..producers)
        .map(|t| {
            let key = api_keys[t % api_keys.len()].clone();
            std::thread::spawn(move || drive_http_producer(addr, &key, t, per_producer, seed))
        })
        .collect();
    let (mut served, mut rejected, mut errors) = (0usize, 0usize, 0usize);
    let mut trace_ids = Vec::with_capacity(producers * per_producer);
    for h in handles {
        let (s, r, e, ids) = h.join().unwrap();
        served += s;
        rejected += r;
        errors += e;
        trace_ids.extend(ids);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    HttpLoadReport {
        connections: producers,
        attempted: producers * per_producer,
        served,
        rejected,
        errors,
        trace_ids,
        wall_s,
    }
}

/// One producer's life: submit the whole arrival stream on a single
/// keep-alive connection, then poll every ticket to a terminal state.
/// Returns (served, rejected, errors, trace ids of admitted submits).
fn drive_http_producer(
    addr: SocketAddr,
    key: &str,
    t: usize,
    per_producer: usize,
    seed: u64,
) -> (usize, usize, usize, Vec<String>) {
    let Ok(mut client) = HttpClient::connect(addr) else {
        return (0, 0, per_producer, Vec::new());
    };
    let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // separate stream for traceparent minting so the prompt sequence stays
    // identical to run_open_loop's (same seed, same prompts, only the
    // transport differs)
    let mut trace_rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x5452_4143_45);
    let mut ids: Vec<u64> = Vec::with_capacity(per_producer);
    let mut trace_ids: Vec<String> = Vec::with_capacity(per_producer);
    let mut errors = 0usize;
    for i in 0..per_producer {
        let class = class_for(i);
        let body = Json::obj(vec![
            ("prompt", Json::str(&prompt_for(class, &mut rng))),
            ("priority", Json::str(priority_name(priority_for(class)))),
            ("deadline_ms", Json::num(1e12)),
        ]);
        let tp = format_traceparent(
            TraceId(((trace_rng.next_u64() as u128) << 64) | trace_rng.next_u64() as u128 | 1),
            SpanId(trace_rng.next_u64() | 1),
        );
        match client.request_traced("POST", "/v1/submit", Some(key), Some(&body), Some(&tp)) {
            Ok(resp) if resp.status == 200 => match resp.json().as_ref().and_then(|j| j.get("ticket").as_i64()) {
                Some(id) => {
                    ids.push(id as u64);
                    if let Some(hex) = resp.json().as_ref().and_then(|j| j.get("trace_id").as_str().map(String::from)) {
                        trace_ids.push(hex);
                    }
                }
                None => errors += 1,
            },
            // 401/429/400/5xx: the server refused before admitting — no
            // ticket exists, nothing to poll
            Ok(_) | Err(_) => errors += 1,
        }
    }
    let (mut served, mut rejected) = (0usize, 0usize);
    'tickets: for id in ids {
        let path = format!("/v1/tickets/{id}");
        let give_up = Instant::now() + HTTP_POLL_DEADLINE;
        loop {
            let Ok(resp) = client.request("GET", &path, Some(key), None) else {
                errors += 1;
                continue 'tickets;
            };
            let parsed = if resp.status == 200 { resp.json() } else { None };
            let Some(json) = parsed else {
                errors += 1;
                continue 'tickets;
            };
            if json.get("done").as_bool() == Some(true) {
                match json.get("outcome").get("outcome").as_str() {
                    Some("served") => served += 1,
                    Some(_) => rejected += 1,
                    // `{"done":true,"error":...}`: the ticket itself failed
                    None => errors += 1,
                }
                continue 'tickets;
            }
            if Instant::now() > give_up {
                errors += 1;
                continue 'tickets;
            }
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    (served, rejected, errors, trace_ids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::mist::Mist;
    use crate::config::{preset_personal_group, Config};
    use crate::islands::Fleet;
    use crate::server::Backend;

    fn orchestrator() -> Arc<Orchestrator> {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        cfg.budget_ceiling = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 77);
        Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 77))
    }

    #[test]
    fn single_thread_closed_loop_accounts_everything() {
        let orch = orchestrator();
        let report = run_closed_loop(&orch, 1, 40, 1);
        assert_eq!(report.attempted, 40);
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 40);
        assert_eq!(orch.audit.len(), 40);
        assert!(report.requests_per_sec() > 0.0);
    }

    #[test]
    fn churned_closed_loop_loses_nothing_and_restores_fleet() {
        let orch = orchestrator();
        let (report, _churn) = run_closed_loop_churn(&orch, 4, 30, 5, Some(Churn::default()));
        assert_eq!(report.attempted, 120);
        assert_eq!(report.errors, 0, "churn must never surface as submit errors");
        assert_eq!(report.outcomes.len(), 120);
        // one audit entry per admitted request, even under churn
        assert_eq!(orch.audit.len(), 120);
        assert_eq!(report.served() + report.rejected(), 120);
        // the fleet is restored for follow-up phases
        let ids = orch.island_ids();
        assert_eq!(ids.len(), 7, "every island rejoined");
        for id in ids {
            let snapshot = orch.island_snapshot(id).unwrap();
            assert!(snapshot.online, "{} left offline", snapshot.spec.name);
        }
    }

    #[test]
    fn open_loop_accounts_every_ticket() {
        let orch = orchestrator();
        let report = run_open_loop(&orch, 4, 24, 3);
        assert_eq!(report.attempted, 96);
        assert_eq!(report.errors, 0, "no ticket may resolve with an error");
        assert_eq!(report.outcomes.len(), 96);
        assert_eq!(report.served() + report.rejected(), 96);
        // exactly one audit entry per enqueued request
        assert_eq!(orch.audit.len(), 96);
        let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.request_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 96);
        assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
        assert!(report.requests_per_sec() > 0.0);
    }

    #[test]
    fn open_loop_http_accounts_every_ticket() {
        use crate::server::{HttpConfig, HttpServer};
        let orch = orchestrator();
        let grants =
            vec![("lg-key-a".to_string(), "http-loadgen-a".to_string()), ("lg-key-b".to_string(), "http-loadgen-b".to_string())];
        let server = HttpServer::start(
            Arc::clone(&orch),
            "127.0.0.1:0",
            &grants,
            HttpConfig { rate_per_sec: 1e9, burst: 1e9, ..HttpConfig::default() },
        )
        .expect("bind loopback");
        let keys: Vec<String> = grants.iter().map(|(k, _)| k.clone()).collect();
        let report = run_open_loop_http(server.addr(), &keys, 2, 12, 9);
        assert_eq!(report.attempted, 24);
        assert_eq!(report.errors, 0, "healthy server: every submit admitted, every poll terminal");
        assert_eq!(report.served + report.rejected, 24);
        // exactly one audit entry per wire submission
        assert_eq!(orch.audit.len(), 24);
        assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
        assert!(report.requests_per_sec() > 0.0);
        // every admitted submit returned the trace id minted by the
        // producer's traceparent — one distinct trace per request
        assert_eq!(report.trace_ids.len(), 24);
        let mut unique = report.trace_ids.clone();
        unique.sort();
        unique.dedup();
        assert_eq!(unique.len(), 24, "client-minted trace ids must be adopted per request");
        server.shutdown();
    }

    #[test]
    fn multi_thread_closed_loop_is_lossless() {
        let orch = orchestrator();
        let report = run_closed_loop(&orch, 4, 25, 2);
        assert_eq!(report.attempted, 100);
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 100);
        assert_eq!(report.served() + report.rejected(), 100);
        // one audit entry per admitted submission
        assert_eq!(orch.audit.len(), 100);
        let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.request_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
    }
}
