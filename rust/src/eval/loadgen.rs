//! Closed-loop multi-threaded load generator for the concurrent submit path.
//!
//! Each worker owns a session and drives the orchestrator in a closed loop
//! (next request issues as soon as the previous one returns), submitting a
//! seeded mixed-sensitivity workload and nudging the virtual clock so the
//! Sim fleet's slots keep clearing. Used by `benches/throughput.rs` and the
//! concurrency stress test; returns the per-request outcomes so callers can
//! cross-check ids, audit entries and ledger totals.

use std::sync::{Arc, Mutex};

use crate::server::{Orchestrator, Outcome};
use crate::substrate::trace::{priority_for, prompt_for, SensClass};
use crate::util::Rng;

/// Aggregate result of one closed-loop run.
#[derive(Debug)]
pub struct LoadReport {
    pub threads: usize,
    /// Requests attempted (threads × per_thread).
    pub attempted: usize,
    /// Outcomes of admitted requests (served or fail-closed rejections).
    pub outcomes: Vec<Outcome>,
    /// Submissions refused before routing (rate limit / session errors).
    pub errors: usize,
    pub wall_s: f64,
}

impl LoadReport {
    pub fn served(&self) -> usize {
        self.outcomes.iter().filter(|o| o.decision.target().is_some()).count()
    }

    pub fn rejected(&self) -> usize {
        self.outcomes.len() - self.served()
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.attempted as f64 / self.wall_s
        }
    }
}

fn class_for(i: usize) -> SensClass {
    match i % 4 {
        0 => SensClass::High,
        1 | 2 => SensClass::Moderate,
        _ => SensClass::Low,
    }
}

/// Turns per conversation before a worker opens a fresh session. Keeps the
/// workload realistic (short chats) and bounds the per-submit history that
/// MIST re-analyzes — one endless session would make the closed loop
/// quadratic in requests.
const SESSION_TURNS: usize = 8;

/// Drive `threads` workers × `per_thread` closed-loop submissions through a
/// shared orchestrator. Deterministic prompt streams per (seed, worker).
pub fn run_closed_loop(orch: &Arc<Orchestrator>, threads: usize, per_thread: usize, seed: u64) -> LoadReport {
    let outcomes = Arc::new(Mutex::new(Vec::with_capacity(threads * per_thread)));
    let errors = Arc::new(Mutex::new(0usize));
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let orch = Arc::clone(orch);
            let outcomes = Arc::clone(&outcomes);
            let errors = Arc::clone(&errors);
            std::thread::spawn(move || {
                let user = format!("loadgen-{t}");
                let mut session = orch.open_session(&user);
                let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut local = Vec::with_capacity(per_thread);
                let mut local_errors = 0usize;
                for i in 0..per_thread {
                    if i > 0 && i % SESSION_TURNS == 0 {
                        session = orch.open_session(&user);
                    }
                    let class = class_for(i);
                    let prompt = prompt_for(class, &mut rng);
                    match orch.submit(session, &prompt, priority_for(class), None) {
                        Ok(out) => local.push(out),
                        Err(_) => local_errors += 1,
                    }
                    // keep virtual time moving so slots clear and token
                    // buckets refill; atomic, so safe from every worker
                    orch.advance(5.0);
                }
                outcomes.lock().unwrap().extend(local);
                *errors.lock().unwrap() += local_errors;
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let outcomes = Arc::try_unwrap(outcomes).expect("workers joined").into_inner().unwrap();
    let errors = *errors.lock().unwrap();
    LoadReport { threads, attempted: threads * per_thread, outcomes, errors, wall_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::mist::Mist;
    use crate::config::{preset_personal_group, Config};
    use crate::islands::Fleet;
    use crate::server::Backend;

    fn orchestrator() -> Arc<Orchestrator> {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        cfg.budget_ceiling = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 77);
        Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 77))
    }

    #[test]
    fn single_thread_closed_loop_accounts_everything() {
        let orch = orchestrator();
        let report = run_closed_loop(&orch, 1, 40, 1);
        assert_eq!(report.attempted, 40);
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 40);
        assert_eq!(orch.audit.len(), 40);
        assert!(report.requests_per_sec() > 0.0);
    }

    #[test]
    fn multi_thread_closed_loop_is_lossless() {
        let orch = orchestrator();
        let report = run_closed_loop(&orch, 4, 25, 2);
        assert_eq!(report.attempted, 100);
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 100);
        assert_eq!(report.served() + report.rejected(), 100);
        // one audit entry per admitted submission
        assert_eq!(orch.audit.len(), 100);
        let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.request_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
    }
}
