//! Evaluation harness: regenerates every table/figure-shaped artifact of the
//! paper (per-experiment index in DESIGN.md §4).
//!
//! - [`harness`]     — trace-driven policy runner with §XI metrics
//! - [`experiments`] — E1..E12 runners
//!
//! Outputs render through [`crate::util::Table`] so EXPERIMENTS.md rows can
//! be pasted verbatim (`islandrun eval all > eval_output/all.md`).

pub mod experiments;
pub mod harness;
pub mod loadgen;

pub use harness::{run_policy, PolicyStats, RunOpts};
pub use loadgen::{run_closed_loop, run_closed_loop_churn, Churn, ChurnStats, LoadReport};
