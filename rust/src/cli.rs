//! Command-line interface (own lightweight parser — clap is unavailable in
//! this offline build, DESIGN.md §2).
//!
//! ```text
//! islandrun eval <e1..e13|all> [--out DIR]   regenerate paper experiments
//! islandrun demo                             §I.A motivating example
//! islandrun attacks                          §VIII.C attack drill
//! islandrun serve [--requests N] [--preset P] real PJRT serving run
//! islandrun serve --addr HOST:PORT [--keys K=USER,..] [--rate RPS]
//!                 [--burst B] [--max-seconds S] HTTP/1.1 network serving
//!                                            surface on the Sim backend
//! islandrun loadgen [--requests N] [--producers P] [--workers W] [--preset P]
//!                                            open-loop run over the
//!                                            enqueue/Ticket queue path (Sim)
//! islandrun loadgen --http [--addr HOST:PORT --keys K1,K2]
//!                                            same arrival schedule, but over
//!                                            real loopback sockets
//! islandrun stats [--requests N] [--preset P] [--prom] [--prom-out FILE]
//!                 [--events-out FILE]        run a short Sim workload and dump
//!                                            telemetry (table or Prometheus)
//! islandrun trace [--requests N] [--preset P] [--out FILE] [--chrome-out FILE]
//!                                            run a Sim workload with trace
//!                                            sampling forced wide open and
//!                                            export the span trees (JSONL and
//!                                            Chrome trace_event)
//! islandrun help
//! ```

use std::path::Path;
use std::sync::Arc;

use crate::agents::mist::{Mist, Stage2};
use crate::config::{preset, Config};
use crate::eval::experiments;
use crate::eval::loadgen::{run_open_loop, run_open_loop_http};
use crate::islands::executor::IslandExecutor;
use crate::islands::Fleet;
use crate::runtime::Engine;
use crate::server::{Backend, HttpConfig, HttpServer, Orchestrator, SubmitRequest};
use crate::telemetry::traceout;

/// Tiny argument scanner: positional args + `--key value` flags.
pub struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // a flag followed by another flag (or nothing) is boolean:
                // store an empty value and do NOT consume the next token
                match argv.get(i + 1) {
                    Some(next) if !next.starts_with("--") => {
                        flags.push((key.to_string(), next.clone()));
                        i += 2;
                    }
                    _ => {
                        flags.push((key.to_string(), String::new()));
                        i += 1;
                    }
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

const HELP: &str = "islandrun — privacy-aware multi-objective orchestration (paper reproduction)

USAGE:
  islandrun eval <e1..e13|all> [--out DIR]   regenerate paper experiments
  islandrun demo                             run the §I.A motivating example
  islandrun attacks                          run the §VIII.C attack drill
  islandrun serve [--requests N] [--preset personal|healthcare|legal|hiking]
                  [--artifacts DIR]          serve a real workload via PJRT
  islandrun serve --addr HOST:PORT [--keys KEY=USER,...] [--rate RPS]
                  [--burst B] [--workers W] [--preset P] [--max-seconds S]
                                             network serving surface: HTTP/1.1
                                             submit/poll/stream/cancel endpoints
                                             with Bearer-key auth, /metrics and
                                             /healthz, on the Sim backend
  islandrun loadgen [--requests N] [--producers P] [--workers W]
                  [--preset personal|healthcare|legal|hiking]
                                             open-loop run over the non-blocking
                                             enqueue/Ticket path (Sim backend)
  islandrun loadgen --http [--addr HOST:PORT --keys KEY1,KEY2]
                                             socket-true open loop: the same
                                             arrival schedule over real loopback
                                             TCP (spins an ephemeral server when
                                             no --addr is given)
  islandrun stats [--requests N] [--preset P] [--prom] [--prom-out FILE]
                  [--events-out FILE]        run a short Sim workload and print
                                             telemetry: the metrics table, or
                                             Prometheus text exposition (--prom);
                                             optionally write the exposition and
                                             the per-request analytics JSONL
  islandrun trace [--requests N] [--preset P] [--out FILE] [--chrome-out FILE]
                                             run a Sim workload with trace
                                             sampling forced wide open, print
                                             the sampling summary, and export
                                             the kept span trees: one JSON
                                             object per line (--out) and the
                                             Chrome trace_event document
                                             (--chrome-out, loadable in
                                             chrome://tracing or Perfetto)
  islandrun help                             this message
";

/// CLI entry point (called from main).
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(run(&argv));
}

/// Testable CLI runner; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    match args.pos(0) {
        Some("eval") => cmd_eval(&args),
        Some("demo") => cmd_demo(),
        Some("attacks") => cmd_attacks(),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("stats") => cmd_stats(&args),
        Some("trace") => cmd_trace(&args),
        Some("help") | None => {
            print!("{HELP}");
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    }
}

fn cmd_eval(args: &Args) -> i32 {
    let which = args.pos(1).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" { experiments::ALL.to_vec() } else { vec![which] };
    let out_dir = args.flag("out").map(|s| s.to_string());
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir).ok();
    }
    for id in ids {
        match experiments::run(id) {
            None => {
                eprintln!("unknown experiment '{id}' (e1..e13)");
                return 2;
            }
            Some(tables) => {
                let mut text = String::new();
                for t in &tables {
                    text.push_str(&t.render());
                    text.push('\n');
                }
                print!("{text}");
                if let Some(dir) = &out_dir {
                    let path = Path::new(dir).join(format!("{id}.md"));
                    if let Err(e) = std::fs::write(&path, &text) {
                        eprintln!("write {}: {e}", path.display());
                    }
                }
            }
        }
    }
    0
}

fn cmd_demo() -> i32 {
    for t in experiments::e8_motivating() {
        t.print();
    }
    0
}

fn cmd_attacks() -> i32 {
    let outcomes = crate::security::run_all();
    let mut ok = true;
    for o in &outcomes {
        println!("{:<28} mitigated={} {}", o.name, o.mitigated, o.details);
        ok &= o.mitigated;
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_serve(args: &Args) -> i32 {
    if args.flag("addr").is_some() {
        return cmd_serve_http(args);
    }
    let n: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(16);
    let preset_name = args.flag("preset").unwrap_or("personal");
    let artifacts = args.flag("artifacts").unwrap_or("artifacts");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let engine = match Engine::load(Path::new(artifacts)) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load artifacts: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    let executor = IslandExecutor::new(engine.handle(), 7);
    let mist = Mist::new(Stage2::Classifier(engine.handle()));
    let backend = Backend::Real { executor, islands };
    let orch = Orchestrator::new(Config::default(), mist, backend, 7);
    let session = orch.open_session("cli-user");

    let mut rng = crate::util::Rng::new(3);
    let t0 = std::time::Instant::now();
    let mut served = 0usize;
    for i in 0..n {
        let class = match i % 4 {
            0 => crate::substrate::trace::SensClass::High,
            1 | 2 => crate::substrate::trace::SensClass::Moderate,
            _ => crate::substrate::trace::SensClass::Low,
        };
        let prompt = crate::substrate::trace::prompt_for(class, &mut rng);
        let priority = crate::substrate::trace::priority_for(class);
        match orch.submit_request(session, SubmitRequest::new(prompt.as_str()).priority(priority)) {
            Ok(out) => {
                served += 1;
                println!(
                    "[{i:>3}] s_r={:.2} -> {:?} {:>7.1}ms ${:.4} | {}…",
                    out.s_r,
                    out.decision.target(),
                    out.latency_ms,
                    out.cost,
                    &prompt[..prompt.len().min(48)],
                );
            }
            Err(e) => println!("[{i:>3}] error: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\nserved {served}/{n} in {wall:.2}s ({:.2} req/s)", served as f64 / wall);
    orch.metrics.report().print();
    0
}

/// Parse `--keys` grants: comma-separated `key=user` pairs mapping each
/// bearer API key to the user it bills to.
fn parse_keys(spec: &str) -> Result<Vec<(String, String)>, String> {
    let mut grants = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let Some((key, user)) = part.split_once('=') else {
            return Err(format!("bad --keys entry '{part}' (expected KEY=USER)"));
        };
        if key.is_empty() || user.is_empty() {
            return Err(format!("bad --keys entry '{part}' (empty key or user)"));
        }
        grants.push((key.to_string(), user.to_string()));
    }
    if grants.is_empty() {
        return Err("--keys must list at least one KEY=USER grant".to_string());
    }
    Ok(grants)
}

/// `serve --addr`: expose the orchestrator over the dependency-free
/// HTTP/1.1 surface on the Sim backend. The PJRT in-process `serve` path
/// (no `--addr`) is untouched. Admission is enforced per API key by the
/// HTTP front door's token bucket (`--rate`/`--burst`), so the
/// orchestrator's own limiter is opened wide to avoid double-charging.
fn cmd_serve_http(args: &Args) -> i32 {
    let addr = args.flag("addr").filter(|a| !a.is_empty()).unwrap_or("127.0.0.1:8080");
    let keys_spec = args.flag("keys").filter(|k| !k.is_empty()).unwrap_or("dev-key=cli-user");
    let grants = match parse_keys(keys_spec) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let preset_name = args.flag("preset").filter(|p| !p.is_empty()).unwrap_or("personal");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let rate: f64 = args.flag("rate").and_then(|s| s.parse().ok()).unwrap_or(50.0);
    let burst: f64 = args.flag("burst").and_then(|s| s.parse().ok()).unwrap_or(rate);
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = workers;
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, 7)), 7));
    let http_cfg = HttpConfig { rate_per_sec: rate.max(0.0), burst: burst.max(1.0), ..HttpConfig::default() };
    let server = match HttpServer::start(Arc::clone(&orch), addr, &grants, http_cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    println!("listening on http://{} — preset '{preset_name}', {} API key(s), Sim backend", server.addr(), grants.len());
    println!("endpoints: POST /v1/submit · GET /v1/tickets/:id · GET /v1/stream/:id · POST /v1/tickets/:id/cancel · GET /metrics · GET /healthz");
    match args.flag("max-seconds").and_then(|s| s.parse::<f64>().ok()) {
        Some(secs) => {
            // bounded run (tests / smoke): serve, drain, report
            std::thread::sleep(std::time::Duration::from_secs_f64(secs.max(0.0)));
            server.shutdown();
            orch.metrics.report().print();
        }
        None => loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
    0
}

/// Open-loop load generation over the non-blocking request lifecycle
/// (enqueue → admit → queue → route → batch → execute → resolve) on the
/// Sim backend: producers push the whole arrival stream through
/// `Orchestrator::enqueue`, the worker pool drains and coalesces it, and
/// every `Ticket` is awaited. Prints the lifecycle metrics (queue waits,
/// sheds, batch grouping) that the blocking path cannot exhibit.
fn cmd_loadgen(args: &Args) -> i32 {
    if args.flag("http").is_some() {
        return cmd_loadgen_http(args);
    }
    let total: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(400);
    let producers: usize = args.flag("producers").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let preset_name = args.flag("preset").unwrap_or("personal");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let mut cfg = Config::default();
    // the generator measures the queue pipeline, not admission policy
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = workers;
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, 7)), 7));
    // round per-producer UP so at least the requested count actually runs
    let per_producer = ((total + producers - 1) / producers).max(1);
    let report = run_open_loop(&orch, producers, per_producer, 11);

    let mut t = crate::util::Table::new("loadgen — open-loop enqueue/Ticket lifecycle (Sim)", &["metric", "value"]);
    t.row(&["producers x per-producer".into(), format!("{} x {per_producer}", report.threads)]);
    t.row(&["attempted".into(), report.attempted.to_string()]);
    t.row(&["served".into(), report.served().to_string()]);
    t.row(&["rejected (fail-closed + shed)".into(), report.rejected().to_string()]);
    t.row(&["ticket errors".into(), report.errors.to_string()]);
    t.row(&["shed: queue full".into(), orch.metrics.counter_value("rejected_queue_full").to_string()]);
    t.row(&["shed: deadline expired".into(), orch.metrics.counter_value("shed_deadline_expired").to_string()]);
    t.row(&["throughput".into(), format!("{:.0} req/s", report.requests_per_sec())]);
    if let Some(h) = orch.metrics.histogram("queue_wait_ms") {
        t.row(&["queue wait p50 / p99 (virtual ms)".into(), format!("{:.1} / {:.1}", h.p50(), h.p99())]);
    }
    if let Some(h) = orch.metrics.histogram("batch_group_size") {
        t.row(&["batch groups (mean size)".into(), format!("{} ({:.2})", h.count(), h.mean())]);
    }
    t.print();
    if report.errors != 0 {
        eprintln!("{} tickets resolved with an error — no ticket may be lost", report.errors);
        return 1;
    }
    0
}

/// `loadgen --http`: the socket-true twin of the in-process open loop —
/// identical arrival schedule, but every request crosses a real loopback
/// TCP connection through `POST /v1/submit` / `GET /v1/tickets/:id`. With
/// `--addr` + `--keys` it drives an already-running server (keys are raw
/// bearer tokens, comma-separated); without `--addr` it spins an ephemeral
/// Sim-backed server so the command is self-contained.
fn cmd_loadgen_http(args: &Args) -> i32 {
    let total: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(400);
    let producers: usize = args.flag("producers").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let per_producer = ((total + producers - 1) / producers).max(1);
    if let Some(addr_spec) = args.flag("addr").filter(|a| !a.is_empty()) {
        use std::net::ToSocketAddrs;
        let Some(addr) = addr_spec.to_socket_addrs().ok().and_then(|mut it| it.next()) else {
            eprintln!("cannot resolve --addr '{addr_spec}'");
            return 2;
        };
        let keys: Vec<String> =
            args.flag("keys").unwrap_or("").split(',').filter(|k| !k.is_empty()).map(String::from).collect();
        if keys.is_empty() {
            eprintln!("--http with --addr needs --keys KEY1,KEY2 (raw bearer tokens)");
            return 2;
        }
        let report = run_open_loop_http(addr, &keys, producers, per_producer, 11);
        print_http_load_report(&report, None);
        return if report.errors == 0 { 0 } else { 1 };
    }
    // self-contained: ephemeral loopback server on the Sim backend
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let preset_name = args.flag("preset").filter(|p| !p.is_empty()).unwrap_or("personal");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = workers;
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, 7)), 7));
    // the generator measures transport + queue behavior, not admission
    let http_cfg = HttpConfig { rate_per_sec: 1e9, burst: 1e9, ..HttpConfig::default() };
    let grants = vec![("loadgen-key".to_string(), "http-loadgen".to_string())];
    let server = match HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, http_cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind loopback: {e}");
            return 1;
        }
    };
    let report = run_open_loop_http(server.addr(), &["loadgen-key".to_string()], producers, per_producer, 11);
    server.shutdown();
    print_http_load_report(&report, Some(&orch));
    if report.errors != 0 {
        eprintln!("{} requests errored on the wire — no ticket may be lost", report.errors);
        return 1;
    }
    0
}

fn print_http_load_report(report: &crate::eval::loadgen::HttpLoadReport, orch: Option<&Arc<Orchestrator>>) {
    let mut t = crate::util::Table::new("loadgen --http — open loop over loopback TCP", &["metric", "value"]);
    t.row(&["connections x per-connection".into(), format!("{} x {}", report.connections, report.attempted / report.connections.max(1))]);
    t.row(&["attempted".into(), report.attempted.to_string()]);
    t.row(&["served".into(), report.served.to_string()]);
    t.row(&["rejected (fail-closed + shed)".into(), report.rejected.to_string()]);
    t.row(&["wire errors".into(), report.errors.to_string()]);
    t.row(&["throughput".into(), format!("{:.0} req/s", report.requests_per_sec())]);
    if let Some(orch) = orch {
        t.row(&["server audit entries".into(), orch.audit.len().to_string()]);
        if let Some(h) = orch.metrics.histogram("queue_wait_ms") {
            t.row(&["queue wait p50 / p99 (virtual ms)".into(), format!("{:.1} / {:.1}", h.p50(), h.p99())]);
        }
        let submit_label = vec!["submit".to_string()];
        if let Some((_, h)) =
            orch.metrics.histogram_children("http_request_ms").into_iter().find(|(labels, _)| labels == &submit_label)
        {
            t.row(&["http submit p50 / p99 (wall ms)".into(), format!("{:.2} / {:.2}", h.p50(), h.p99())]);
        }
    }
    t.print();
}

/// Drive a short deterministic Sim workload through the queue path and
/// expose the resulting telemetry: the human-readable metrics table by
/// default, the Prometheus text exposition with `--prom`, plus optional
/// file dumps (`--prom-out`, `--events-out`) for CI artifacts. The
/// exposition is format-linted before it is printed or written.
fn cmd_stats(args: &Args) -> i32 {
    let total: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(200);
    let preset_name = args.flag("preset").filter(|p| !p.is_empty()).unwrap_or("personal");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, 7)), 7));
    let report = run_open_loop(&orch, 2, (total + 1) / 2, 11);

    let exposition = orch.metrics.render_prometheus();
    if let Err(e) = crate::telemetry::lint_exposition(&exposition) {
        eprintln!("render_prometheus produced an invalid exposition: {e}");
        return 1;
    }
    if args.flag("prom").is_some() {
        print!("{exposition}");
    } else {
        println!("stats — {} requests on '{preset_name}' (Sim), {} served", report.attempted, report.served());
        orch.metrics.report().print();
    }
    if let Some(path) = args.flag("prom-out").filter(|p| !p.is_empty()) {
        if let Err(e) = std::fs::write(path, &exposition) {
            eprintln!("write {path}: {e}");
            return 1;
        }
    }
    if let Some(path) = args.flag("events-out").filter(|p| !p.is_empty()) {
        if let Err(e) = std::fs::write(path, orch.analytics.to_jsonl()) {
            eprintln!("write {path}: {e}");
            return 1;
        }
    }
    0
}

/// Run a short deterministic Sim workload with trace sampling forced wide
/// open (head rate 1.0, ring sized to the run) and export the kept span
/// trees: JSONL via `--out` (one trace object per line, the same shape
/// `GET /v1/traces/:id` serves) and the Chrome `trace_event` document via
/// `--chrome-out`. Prints the sampling summary either way.
fn cmd_trace(args: &Args) -> i32 {
    let total: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(64);
    let preset_name = args.flag("preset").filter(|p| !p.is_empty()).unwrap_or("personal");
    let Some(islands) = preset(preset_name) else {
        eprintln!("unknown preset '{preset_name}'");
        return 2;
    };
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    // exporting is the point of this command: keep every trace the run
    // produces instead of the serving default's tail-sampled subset
    cfg.trace_enabled = true;
    cfg.trace_head_rate = 1.0;
    cfg.trace_ring_capacity = total.max(64);
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, 7)), 7));
    let report = run_open_loop(&orch, 2, (total + 1) / 2, 11);
    let traces = orch.traces.snapshot();

    let mut t = crate::util::Table::new("trace — request span trees (Sim, sampling wide open)", &["metric", "value"]);
    t.row(&["requests attempted / served".into(), format!("{} / {}", report.attempted, report.served())]);
    t.row(&["traces started".into(), orch.traces.started().to_string()]);
    t.row(&["traces kept".into(), orch.traces.kept().to_string()]);
    t.row(&["traces sampled out".into(), orch.traces.sampled_out().to_string()]);
    t.row(&["ring occupancy".into(), traces.len().to_string()]);
    if let Some(slowest) = traces.iter().max_by(|a, b| a.duration_ms().total_cmp(&b.duration_ms())) {
        t.row(&[
            "slowest trace".into(),
            format!(
                "{} {:.1}ms ({} spans, {}/{})",
                slowest.trace_id.to_hex(),
                slowest.duration_ms(),
                slowest.spans.len(),
                slowest.outcome,
                slowest.reason
            ),
        ]);
    }
    t.print();
    if let Some(path) = args.flag("out").filter(|p| !p.is_empty()) {
        if let Err(e) = std::fs::write(path, traceout::to_jsonl(&traces)) {
            eprintln!("write {path}: {e}");
            return 1;
        }
    }
    if let Some(path) = args.flag("chrome-out").filter(|p| !p.is_empty()) {
        if let Err(e) = std::fs::write(path, traceout::to_chrome_json(&traces).to_string()) {
            eprintln!("write {path}: {e}");
            return 1;
        }
    }
    if traces.is_empty() {
        eprintln!("no traces kept — the run resolved no requests, so there is nothing to export");
        return 1;
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_positionals_and_flags() {
        let a = Args::parse(&argv(&["eval", "e2", "--out", "/tmp/x"]));
        assert_eq!(a.pos(0), Some("eval"));
        assert_eq!(a.pos(1), Some("e2"));
        assert_eq!(a.flag("out"), Some("/tmp/x"));
        assert_eq!(a.flag("missing"), None);
    }

    #[test]
    fn help_and_unknown() {
        assert_eq!(run(&argv(&["help"])), 0);
        assert_eq!(run(&argv(&[])), 0);
        assert_eq!(run(&argv(&["frobnicate"])), 2);
    }

    #[test]
    fn eval_unknown_experiment_errors() {
        assert_eq!(run(&argv(&["eval", "e99"])), 2);
    }

    #[test]
    fn attacks_command_passes() {
        assert_eq!(run(&argv(&["attacks"])), 0);
    }

    #[test]
    fn boolean_flags_do_not_swallow_the_next_flag() {
        let a = Args::parse(&argv(&["stats", "--prom", "--prom-out", "/tmp/x.prom"]));
        assert_eq!(a.flag("prom"), Some(""));
        assert_eq!(a.flag("prom-out"), Some("/tmp/x.prom"));
        let b = Args::parse(&argv(&["stats", "--prom"]));
        assert_eq!(b.flag("prom"), Some(""));
    }

    #[test]
    fn stats_command_emits_lintable_exposition_and_events() {
        let dir = std::env::temp_dir();
        let prom = dir.join("islandrun_cli_stats.prom");
        let events = dir.join("islandrun_cli_stats.jsonl");
        let code = run(&argv(&[
            "stats",
            "--requests",
            "32",
            "--prom",
            "--prom-out",
            prom.to_str().unwrap(),
            "--events-out",
            events.to_str().unwrap(),
        ]));
        assert_eq!(code, 0);
        let text = std::fs::read_to_string(&prom).unwrap();
        crate::telemetry::lint_exposition(&text).unwrap();
        assert!(text.contains("islandrun_requests_resolved_total"), "outcome family missing:\n{text}");
        let jsonl = std::fs::read_to_string(&events).unwrap();
        assert!(!jsonl.trim().is_empty(), "analytics JSONL must cover the resolved requests");
        let first = crate::config::json::Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert!(first.get("outcome").as_str().is_some());
        let _ = std::fs::remove_file(&prom);
        let _ = std::fs::remove_file(&events);
    }

    #[test]
    fn trace_command_exports_jsonl_and_chrome_artifacts() {
        let dir = std::env::temp_dir();
        let jsonl = dir.join("islandrun_cli_traces.jsonl");
        let chrome = dir.join("islandrun_cli_traces_chrome.json");
        let code = run(&argv(&[
            "trace",
            "--requests",
            "24",
            "--out",
            jsonl.to_str().unwrap(),
            "--chrome-out",
            chrome.to_str().unwrap(),
        ]));
        assert_eq!(code, 0);
        let text = std::fs::read_to_string(&jsonl).unwrap();
        assert!(!text.trim().is_empty(), "a wide-open run must keep traces");
        let first = crate::config::json::Json::parse(text.lines().next().unwrap()).unwrap();
        assert!(first.get("trace_id").as_str().is_some());
        assert!(first.get("root").get("span_id").as_str().is_some());
        assert!(first.get("outcome").as_str().is_some());
        let doc = crate::config::json::Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
        let events = doc.get("traceEvents").as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get("name").as_str() == Some("request")),
            "every trace exports its root span as a Chrome event"
        );
        assert_eq!(run(&argv(&["trace", "--preset", "nonexistent"])), 2);
        let _ = std::fs::remove_file(&jsonl);
        let _ = std::fs::remove_file(&chrome);
    }

    #[test]
    fn loadgen_command_drives_the_queue_path() {
        assert_eq!(run(&argv(&["loadgen", "--requests", "32", "--producers", "2", "--workers", "2"])), 0);
        assert_eq!(run(&argv(&["loadgen", "--preset", "nonexistent"])), 2);
    }

    #[test]
    fn parse_keys_accepts_grants_and_rejects_garbage() {
        let grants = parse_keys("a=alice,b=bob").unwrap();
        assert_eq!(grants.len(), 2);
        assert_eq!(grants[0], ("a".to_string(), "alice".to_string()));
        assert!(parse_keys("").is_err());
        assert!(parse_keys("noequals").is_err());
        assert!(parse_keys("=user").is_err());
        assert!(parse_keys("key=").is_err());
    }

    #[test]
    fn serve_addr_starts_serves_and_drains() {
        let code = run(&argv(&["serve", "--addr", "127.0.0.1:0", "--keys", "k=cli-user", "--max-seconds", "0"]));
        assert_eq!(code, 0);
        assert_eq!(run(&argv(&["serve", "--addr", "127.0.0.1:0", "--keys", "malformed"])), 2);
    }

    #[test]
    fn loadgen_http_drives_the_socket_path() {
        assert_eq!(run(&argv(&["loadgen", "--http", "--requests", "16", "--producers", "2", "--workers", "2"])), 0);
        // external-server mode without keys is a usage error
        assert_eq!(run(&argv(&["loadgen", "--http", "--addr", "127.0.0.1:1"])), 2);
    }
}
