//! Plain-text table rendering for experiment reports and benches.
//!
//! The eval harness (`eval::*`) prints every regenerated paper table/figure
//! through this type so EXPERIMENTS.md rows can be pasted verbatim.

/// A simple left-aligned text table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table { title: title.to_string(), header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row; panics if the arity differs from the header.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch in table '{}'", self.title);
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from &str slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned monospace table (also valid GitHub markdown).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push(' ');
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with fixed decimals — table-cell helper.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format a fraction as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("demo", &["policy", "violations"]);
        t.row_str(&["islandrun", "0"]);
        t.row_str(&["latency-greedy", "4000"]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| policy"));
        assert!(r.lines().count() == 5);
        // markdown separator present
        assert!(r.lines().nth(2).unwrap().starts_with("|-"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn helpers_format() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.1234), "12.3%");
    }
}
