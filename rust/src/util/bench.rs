//! Micro-benchmark harness (offline stand-in for criterion): warmup +
//! timed iterations with mean/percentile reporting. Used by every target in
//! `rust/benches/` (`cargo bench` with `harness = false`).

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_us <= 0.0 {
            0.0
        } else {
            1e6 / self.mean_us
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        p50_us: stats::percentile(&samples, 0.5),
        p99_us: stats::percentile(&samples, 0.99),
    }
}

/// Render results as a markdown table (pasted into EXPERIMENTS.md).
pub fn report(title: &str, results: &[BenchResult]) {
    let mut t = crate::util::Table::new(title, &["case", "iters", "mean", "p50", "p99", "ops/s"]);
    for r in results {
        t.row(&[
            r.name.clone(),
            r.iters.to_string(),
            fmt_us(r.mean_us),
            fmt_us(r.p50_us),
            fmt_us(r.p99_us),
            format!("{:.0}", r.throughput_per_sec()),
        ]);
    }
    t.print();
}

/// Write a bench's result rows as a JSON artifact when the
/// `ISLANDRUN_BENCH_JSON` env var names a path (the CI bench-smoke job sets
/// it and uploads the file, seeding the bench trajectory). Rows are
/// `(key, value)` pairs per result; the file holds
/// `{"bench": name, "results": [{...}, ...]}`.
pub fn write_json_artifact(bench_name: &str, rows: &[Vec<(String, f64)>]) {
    let Ok(path) = std::env::var("ISLANDRUN_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    use crate::config::json::Json;
    let results: Vec<Json> = rows
        .iter()
        .map(|row| Json::obj(row.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect()))
        .collect();
    let doc = Json::obj(vec![("bench", Json::str(bench_name)), ("results", Json::Arr(results))]);
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => println!("\nwrote bench artifact: {path}"),
        Err(e) => eprintln!("\nfailed to write bench artifact {path}: {e}"),
    }
}

/// Whether speedup gates should assert (`ISLANDRUN_BENCH_GATE=off`
/// disables them — smoke runs measure, they do not gate). Shared by every
/// gated bench so the env contract cannot drift between them.
pub fn gate_enabled() -> bool {
    std::env::var("ISLANDRUN_BENCH_GATE").map(|v| v != "off").unwrap_or(true)
}

/// Human-readable microseconds.
pub fn fmt_us(us: f64) -> String {
    if us < 1000.0 {
        format!("{us:.1}us")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_us > 0.0);
        assert!(r.p99_us >= r.p50_us);
        assert!(r.throughput_per_sec() > 0.0);
    }

    #[test]
    fn json_artifact_round_trips() {
        let path = std::env::temp_dir().join("islandrun_bench_artifact_test.json");
        std::env::set_var("ISLANDRUN_BENCH_JSON", &path);
        write_json_artifact(
            "unit",
            &[vec![("threads".to_string(), 4.0), ("req_per_s".to_string(), 123.5)]],
        );
        std::env::remove_var("ISLANDRUN_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("unit"));
        assert_eq!(j.get("results").idx(0).get("threads").as_i64(), Some(4));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_us(12.34), "12.3us");
        assert_eq!(fmt_us(1234.0), "1.23ms");
        assert_eq!(fmt_us(2.5e6), "2.50s");
    }
}
