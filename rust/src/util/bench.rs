//! Micro-benchmark harness (offline stand-in for criterion): warmup +
//! timed iterations with mean/percentile reporting. Used by every target in
//! `rust/benches/` (`cargo bench` with `harness = false`).

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_us <= 0.0 {
            0.0
        } else {
            1e6 / self.mean_us
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        p50_us: stats::percentile(&samples, 0.5),
        p99_us: stats::percentile(&samples, 0.99),
    }
}

/// Render results as a markdown table (pasted into EXPERIMENTS.md).
pub fn report(title: &str, results: &[BenchResult]) {
    let mut t = crate::util::Table::new(title, &["case", "iters", "mean", "p50", "p99", "ops/s"]);
    for r in results {
        t.row(&[
            r.name.clone(),
            r.iters.to_string(),
            fmt_us(r.mean_us),
            fmt_us(r.p50_us),
            fmt_us(r.p99_us),
            format!("{:.0}", r.throughput_per_sec()),
        ]);
    }
    t.print();
}

/// Human-readable microseconds.
pub fn fmt_us(us: f64) -> String {
    if us < 1000.0 {
        format!("{us:.1}us")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_us > 0.0);
        assert!(r.p99_us >= r.p50_us);
        assert!(r.throughput_per_sec() > 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_us(12.34), "12.3us");
        assert_eq!(fmt_us(1234.0), "1.23ms");
        assert_eq!(fmt_us(2.5e6), "2.50s");
    }
}
