//! Deterministic pseudo-random numbers (SplitMix64 + xoshiro256**).
//!
//! Every stochastic component in the simulator (netsim jitter, workload
//! generators, attack scripts) takes an explicit `Rng` so experiments are
//! reproducible from a seed recorded in EXPERIMENTS.md.

/// xoshiro256** seeded via SplitMix64. Not cryptographic; simulation only.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std, clamped to [lo, hi].
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        (mean + self.normal() * std).clamp(lo, hi)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independently-seeded child generator.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_spread() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(9);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
