//! Latency/score statistics: streaming histogram with percentile queries.
//!
//! Used by the telemetry registry, the eval harness (E4 latency
//! distributions) and the bench harness. Log-bucketed so a single histogram
//! covers microseconds through minutes with bounded memory.

/// Log-bucketed histogram over positive f64 samples (e.g. milliseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i covers [BASE * GROWTH^i, BASE * GROWTH^(i+1))
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BASE: f64 = 1e-3; // 1 microsecond when samples are in ms
const GROWTH: f64 = 1.07;
const NBUCKETS: usize = 400;

/// Number of log-scaled buckets (shared with the lock-free telemetry
/// histogram so both record into identical bucket grids).
pub(crate) const BUCKETS: usize = NBUCKETS;

/// Bucket index for a sample, after the same clamping [`Histogram::record`]
/// applies (non-finite / negative samples land in bucket 0).
pub(crate) fn bucket_index(x: f64) -> usize {
    let x = if x.is_finite() && x > 0.0 { x } else { 0.0 };
    Histogram::bucket_of(x)
}

/// Upper bound of bucket `i` (exclusive): bucket i covers
/// [BASE * GROWTH^i, BASE * GROWTH^(i+1)).
pub(crate) fn bucket_upper(i: usize) -> f64 {
    Histogram::bucket_lo(i + 1)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: vec![0; NBUCKETS], count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Rebuild a histogram from raw parts — used by the lock-free telemetry
    /// histogram to snapshot its atomic bucket array into this query type.
    /// `buckets` must use the same BASE/GROWTH grid (enforced by length).
    pub(crate) fn from_parts(buckets: Vec<u64>, count: u64, sum: f64, min: f64, max: f64) -> Self {
        assert_eq!(buckets.len(), NBUCKETS, "bucket grid mismatch");
        Histogram { buckets, count, sum, min, max }
    }

    /// Cumulative (bucket, upper-bound) pairs up to and including the last
    /// non-empty bucket — the Prometheus `le` series, excluding `+Inf`.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let last = match self.buckets.iter().rposition(|&c| c > 0) {
            Some(i) => i,
            None => return Vec::new(),
        };
        let mut out = Vec::with_capacity(last + 1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate().take(last + 1) {
            cum += c;
            out.push((Self::bucket_lo(i + 1), cum));
        }
        out
    }

    /// Total of all samples (numerator of [`Histogram::mean`]).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    fn bucket_of(x: f64) -> usize {
        if x <= BASE {
            return 0;
        }
        (((x / BASE).ln() / GROWTH.ln()) as usize).min(NBUCKETS - 1)
    }

    fn bucket_lo(i: usize) -> f64 {
        BASE * GROWTH.powi(i as i32)
    }

    /// Record one sample. Non-finite or negative samples are clamped to 0.
    pub fn record(&mut self, x: f64) {
        let x = if x.is_finite() && x > 0.0 { x } else { 0.0 };
        self.buckets[Self::bucket_of(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile (q in [0,1]) from the bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                // interpolate to the bucket midpoint, clamp to observed range
                let mid = Self::bucket_lo(i) * (1.0 + GROWTH) / 2.0;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// One-line summary, e.g. for report tables.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2} p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.count,
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99(),
            self.max()
        )
    }
}

/// Mean of a slice (0.0 when empty) — small helper for the eval harness.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Exact percentile of a slice by sorting (eval-harness use; not streaming).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((q.clamp(0.0, 1.0)) * (v.len() - 1) as f64).round() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p50(), 0.0);
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = Histogram::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            h.record(x);
        }
        assert!((h.mean() - 2.5).abs() < 1e-9);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
    }

    #[test]
    fn quantiles_monotone_and_bounded() {
        let mut h = Histogram::new();
        let mut r = crate::util::Rng::new(1);
        for _ in 0..10_000 {
            h.record(r.range_f64(1.0, 1000.0));
        }
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 > 300.0 && p50 < 700.0, "p50={p50}");
        assert!(h.min() >= 1.0 && h.max() <= 1000.0);
    }

    #[test]
    fn quantile_accuracy_within_bucket_resolution() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        // log buckets grow 7% — accept 10% relative error
        assert!((h.p50() - 500.0).abs() / 500.0 < 0.10, "p50={}", h.p50());
        assert!((h.p99() - 990.0).abs() / 990.0 < 0.10, "p99={}", h.p99());
    }

    #[test]
    fn merge_equals_union() {
        let (mut a, mut b, mut u) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..500 {
            let x = (i as f64) + 0.5;
            if i % 2 == 0 {
                a.record(x)
            } else {
                b.record(x)
            }
            u.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        assert!((a.mean() - u.mean()).abs() < 1e-9);
        assert_eq!(a.p95(), u.p95());
    }

    #[test]
    fn degenerate_samples_clamped() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(-5.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn slice_percentile_exact() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!((percentile(&xs, 0.5) - 50.0).abs() <= 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
