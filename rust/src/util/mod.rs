//! Small self-contained utilities: deterministic RNG, latency statistics,
//! table rendering and a miniature property-testing harness.
//!
//! These stand in for crates that are unavailable in this offline build
//! (`rand`, `criterion`'s stats, `proptest`); the substitution is recorded in
//! `DESIGN.md` §2.

pub mod atomic;
pub mod bench;
pub mod minicheck;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;

pub use atomic::AtomicF64;
pub use rng::Rng;
pub use stats::Histogram;
pub use table::Table;

/// Replace every ASCII digit run in `text` with a single `#`, e.g.
/// `"[PERSON_4821]"` → `"[PERSON_#]"`. Used by tests comparing sanitized
/// wire text across sessions, where placeholder ids are session-random but
/// kinds and positions must match exactly.
pub fn collapse_digit_runs(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut in_run = false;
    for c in text.chars() {
        if c.is_ascii_digit() {
            if !in_run {
                out.push('#');
                in_run = true;
            }
        } else {
            in_run = false;
            out.push(c);
        }
    }
    out
}
