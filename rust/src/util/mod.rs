//! Small self-contained utilities: deterministic RNG, latency statistics,
//! table rendering and a miniature property-testing harness.
//!
//! These stand in for crates that are unavailable in this offline build
//! (`rand`, `criterion`'s stats, `proptest`); the substitution is recorded in
//! `DESIGN.md` §2.

pub mod atomic;
pub mod bench;
pub mod minicheck;
pub mod rng;
pub mod stats;
pub mod table;

pub use atomic::AtomicF64;
pub use rng::Rng;
pub use stats::Histogram;
pub use table::Table;
