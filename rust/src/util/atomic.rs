//! Lock-free `f64` cell over `AtomicU64` bit transmutation — the building
//! block for the concurrent metrics registry, the cost ledger totals and the
//! fleet's virtual clock.

use std::sync::atomic::{AtomicU64, Ordering};

/// An `f64` with atomic load/store/add. Add uses a CAS loop; all operations
/// are `SeqCst` (these sit on accounting paths, not hot inner loops).
#[derive(Debug, Default)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    pub fn new(value: f64) -> AtomicF64 {
        AtomicF64 { bits: AtomicU64::new(value.to_bits()) }
    }

    pub fn load(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::SeqCst))
    }

    pub fn store(&self, value: f64) {
        self.bits.store(value.to_bits(), Ordering::SeqCst);
    }

    /// Atomically replace `current` with `new` iff the cell still holds
    /// `current` (bitwise comparison). Returns true on success — the caller
    /// won the exchange; racing callers observing the same `current` lose.
    pub fn compare_exchange(&self, current: f64, new: f64) -> bool {
        self.bits.compare_exchange(current.to_bits(), new.to_bits(), Ordering::SeqCst, Ordering::SeqCst).is_ok()
    }

    /// Atomically add `delta`, returning the previous value.
    pub fn fetch_add(&self, delta: f64) -> f64 {
        let mut current = self.bits.load(Ordering::SeqCst);
        loop {
            let next = (f64::from_bits(current) + delta).to_bits();
            match self.bits.compare_exchange(current, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return f64::from_bits(current),
                Err(actual) => current = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn load_store_round_trip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(-2.25);
        assert_eq!(a.load(), -2.25);
        assert_eq!(AtomicF64::default().load(), 0.0);
    }

    #[test]
    fn concurrent_adds_sum_exactly() {
        // powers of two add exactly in f64 regardless of interleaving
        let a = Arc::new(AtomicF64::new(0.0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        a.fetch_add(0.25);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(), 2000.0);
    }

    #[test]
    fn fetch_add_returns_previous() {
        let a = AtomicF64::new(1.0);
        assert_eq!(a.fetch_add(2.0), 1.0);
        assert_eq!(a.load(), 3.0);
    }

    #[test]
    fn compare_exchange_single_winner() {
        let a = AtomicF64::new(1.0);
        assert!(a.compare_exchange(1.0, 2.0));
        assert!(!a.compare_exchange(1.0, 3.0), "stale current must lose");
        assert_eq!(a.load(), 2.0);
        // works for the NEG_INFINITY sentinel too (bitwise compare)
        let b = AtomicF64::new(f64::NEG_INFINITY);
        assert!(b.compare_exchange(f64::NEG_INFINITY, 0.0));
        assert_eq!(b.load(), 0.0);
    }
}
