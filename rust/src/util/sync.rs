//! Poisoned-lock recovery policy for the serving path.
//!
//! **Policy (decided once, applied everywhere):** a poisoned `Mutex`/`RwLock`
//! is *recovered*, never propagated. Poisoning only means some thread
//! panicked while holding the guard; every serving-path critical section in
//! this crate maintains its invariants before blocking or returning (metrics
//! cells are atomics, queues re-validate on drain, registries are
//! last-write-wins maps), so the protected data is still structurally valid.
//! Propagating the `PoisonError` instead would convert one contained panic —
//! already counted and shed by the `catch_unwind` fences in the queue
//! workers and step-loop drivers — into a crash loop that takes down every
//! subsequent request touching the same lock. Fail-closed applies to
//! *requests* (they shed with a typed [`crate::server::Resolution`]), not to
//! the process.
//!
//! Every recovery is counted in [`POISON_RECOVERIES`] and surfaced as
//! `islandrun_lock_poison_recoveries_total` in the Prometheus exposition, so
//! a non-zero value is observable and alertable: it always indicates a
//! panic happened somewhere, even if the panic itself was contained.
//!
//! `islandlint` rule R1 (`serving-path-panic`) denies unwrapping lock
//! results in serving modules; these extension traits are the sanctioned
//! replacement.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult};
use std::time::Duration;

/// Process-wide count of poisoned-lock recoveries. Always zero in a healthy
/// process; non-zero means a thread panicked while holding a guard.
pub static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// Current recovery count (exported to the Prometheus exposition).
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

fn note_recovery() {
    POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
}

/// `Mutex` extension: acquire the guard, recovering from poisoning.
pub trait LockExt<T> {
    /// Like `lock().unwrap()` but recovers a poisoned guard (and counts the
    /// recovery) instead of panicking.
    fn lock_clean(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_clean(&self) -> MutexGuard<'_, T> {
        match self.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                note_recovery();
                poisoned.into_inner()
            }
        }
    }
}

/// `RwLock` extension: acquire read/write guards, recovering from poisoning.
pub trait RwLockExt<T> {
    /// Like `read().unwrap()` but recovers a poisoned guard.
    fn read_clean(&self) -> RwLockReadGuard<'_, T>;
    /// Like `write().unwrap()` but recovers a poisoned guard.
    fn write_clean(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn read_clean(&self) -> RwLockReadGuard<'_, T> {
        match self.read() {
            Ok(g) => g,
            Err(poisoned) => {
                note_recovery();
                poisoned.into_inner()
            }
        }
    }

    fn write_clean(&self) -> RwLockWriteGuard<'_, T> {
        match self.write() {
            Ok(g) => g,
            Err(poisoned) => {
                note_recovery();
                poisoned.into_inner()
            }
        }
    }
}

/// `Condvar::wait` with poison recovery. The guard is handed to the condvar
/// (the lock is *released* while parked), which is why islandlint rule R2
/// (`lock-across-blocking`) exempts guards passed as a blocking call's
/// argument.
pub fn cond_wait<'a, T>(cond: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cond.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

/// `Condvar::wait_while` with poison recovery.
pub fn cond_wait_while<'a, T, F>(cond: &Condvar, guard: MutexGuard<'a, T>, condition: F) -> MutexGuard<'a, T>
where
    F: FnMut(&mut T) -> bool,
{
    match cond.wait_while(guard, condition) {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

/// `Condvar::wait_timeout` with poison recovery.
pub fn cond_wait_timeout<'a, T>(
    cond: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    match cond.wait_timeout(guard, dur) {
        Ok(pair) => pair,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_clean_recovers_poison_and_counts() {
        let before = poison_recoveries();
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock_clean();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        *m.lock_clean() += 1;
        assert_eq!(*m.lock_clean(), 8);
        assert!(poison_recoveries() > before);
    }

    #[test]
    fn rwlock_clean_recovers_poison() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write_clean();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.is_poisoned());
        *l.write_clean() = 2;
        assert_eq!(*l.read_clean(), 2);
    }

    #[test]
    fn cond_wait_helpers_round_trip() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, c) = &*p2;
            *m.lock_clean() = true;
            c.notify_all();
        });
        let (m, c) = &*pair;
        let guard = cond_wait_while(c, m.lock_clean(), |ready| !*ready);
        assert!(*guard);
        drop(guard);
        let (guard, timed_out) = cond_wait_timeout(c, m.lock_clean(), Duration::from_millis(1));
        assert!(*guard);
        let _ = timed_out;
        h.join().unwrap();
    }
}
