//! Miniature property-based testing harness (offline stand-in for proptest).
//!
//! Drives a property over many seeded random cases and, on failure, attempts
//! a simple shrink by re-running with "smaller" generated inputs (generators
//! receive a `size` hint the shrinker walks down). Coordinator invariants —
//! routing, batching, sanitization, trust composition — are property-tested
//! through this harness in `rust/tests/prop_invariants.rs` and per-module
//! unit tests.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum `size` hint passed to the generator
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x15_1A_2D, max_size: 64 }
    }
}

/// Outcome of a single property case.
pub enum CaseResult {
    Pass,
    /// Failure with a human-readable description of the counterexample.
    Fail(String),
}

/// Run `gen` to build a case of the given size, then `prop` to check it.
///
/// Panics with the counterexample description (including seed and size, so
/// the case can be replayed) if any case fails. On failure it first retries
/// the same seed at smaller sizes to report the smallest failing size.
pub fn check<G, T, P>(name: &str, cfg: Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> CaseResult,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // sizes sweep small -> large so early failures are already small
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let CaseResult::Fail(desc) = prop(&input) {
            // shrink: retry same seed at smaller sizes
            let mut min_fail = (size, desc);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                let input = gen(&mut rng, s);
                if let CaseResult::Fail(d) = prop(&input) {
                    min_fail = (s, d);
                    if s == 1 {
                        break;
                    }
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, size {}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert-style helper for building `CaseResult`s.
pub fn ensure(cond: bool, desc: impl FnOnce() -> String) -> CaseResult {
    if cond {
        CaseResult::Pass
    } else {
        CaseResult::Fail(desc())
    }
}

/// Combine multiple sub-checks; first failure wins.
pub fn all(results: Vec<CaseResult>) -> CaseResult {
    for r in results {
        if let CaseResult::Fail(d) = r {
            return CaseResult::Fail(d);
        }
    }
    CaseResult::Pass
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", Config::default(), |rng, _| (rng.next_u64() as u32, rng.next_u64() as u32), |&(a, b)| {
            ensure(a.wrapping_add(b) == b.wrapping_add(a), || "math broke".into())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            Config { cases: 5, ..Config::default() },
            |rng, size| rng.below(size.max(1)),
            |_| CaseResult::Fail("nope".into()),
        );
    }

    #[test]
    fn shrink_reports_small_size() {
        let result = std::panic::catch_unwind(|| {
            check(
                "len-under-3",
                Config { cases: 20, max_size: 64, seed: 1 },
                |rng, size| vec![0u8; 1 + rng.below(size)],
                |v| ensure(v.len() < 3, || format!("len={}", v.len())),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrinker should find a failure at a small size hint
        assert!(msg.contains("size"), "{msg}");
    }

    #[test]
    fn all_combines() {
        assert!(matches!(all(vec![CaseResult::Pass, CaseResult::Pass]), CaseResult::Pass));
        assert!(matches!(all(vec![CaseResult::Pass, CaseResult::Fail("x".into())]), CaseResult::Fail(_)));
    }
}
