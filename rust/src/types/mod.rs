//! Core domain types from the paper's problem formulation (§III).
//!
//! - Def. 1 *Computing Island*: [`Island`] with latency `L_j`, cost `C_j`,
//!   privacy `P_j`, trust `T_j` and time-varying capacity `R_j(t)`.
//! - Def. 2 *Inference Request*: [`Request`] with prompt `q`, modality `m`,
//!   sensitivity `s_r`, latency budget `d_r` and chat history `h_r`.
//! - §III.B island groups and trust tiers: [`TrustTier`].
//! - §IX.B priority tiers: [`PriorityTier`].

use std::fmt;

/// Identifier of an island within a [`crate::agents::lighthouse::Registry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IslandId(pub u32);

impl fmt::Display for IslandId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "island-{}", self.0)
    }
}

/// §III.B three-tier trust hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrustTier {
    /// Tier 1: personal island group (Trust = 1.0) — user's own devices.
    Personal,
    /// Tier 2: private edge (Trust = 0.6–0.8) — organization-controlled.
    PrivateEdge,
    /// Tier 3: unbounded public cloud (Trust = 0.3–0.5).
    Cloud,
}

impl TrustTier {
    /// Paper §VII.C base trust for the tier.
    pub fn base_trust(self) -> f64 {
        match self {
            TrustTier::Personal => 1.0,
            TrustTier::PrivateEdge => 0.8,
            TrustTier::Cloud => 0.5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrustTier::Personal => "personal",
            TrustTier::PrivateEdge => "private-edge",
            TrustTier::Cloud => "cloud",
        }
    }
}

/// §VII.C certification level declared at island registration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Certification {
    Iso27001,
    Soc2,
    SelfCertified,
}

impl Certification {
    pub fn score(self) -> f64 {
        match self {
            Certification::Iso27001 => 1.0,
            Certification::Soc2 => 0.9,
            Certification::SelfCertified => 0.7,
        }
    }
}

/// §VII.C jurisdiction class declared at island registration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Jurisdiction {
    SameCountry,
    EuGdpr,
    Foreign,
}

impl Jurisdiction {
    pub fn score(self) -> f64 {
        match self {
            Jurisdiction::SameCountry => 1.0,
            Jurisdiction::EuGdpr => 0.9,
            Jurisdiction::Foreign => 0.6,
        }
    }
}

/// Network link class between the client (SHORE) and the island; drives the
/// `substrate::netsim` latency/bandwidth model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Same device (the SHORE itself).
    Loopback,
    /// Home / office LAN.
    Lan,
    /// Wide-area internet (cloud providers).
    Wan,
    /// Bluetooth mesh between nearby phones (Scenario 2).
    Bluetooth,
    /// Cellular hotspot (car / hiking scenarios).
    Cellular,
}

/// Cost model declared at registration (§III.B "Island Registration").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// Personal devices: zero marginal cost.
    Free,
    /// Private edge: fixed amortized cost per request ($).
    Fixed(f64),
    /// Cloud: per-1k-token style variable pricing ($ per request at the
    /// reference prompt size, scaled by tokens at accounting time).
    PerRequest(f64),
}

impl CostModel {
    /// Marginal dollar cost of a request with `tokens` total tokens.
    pub fn cost(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Free => 0.0,
            CostModel::Fixed(c) => *c,
            CostModel::PerRequest(c) => c * (tokens.max(1) as f64 / 64.0),
        }
    }
}

/// Static island registration record (Def. 1 + §III.B declaration).
///
/// The *dynamic* state (capacity `R_j(t)`, liveness, battery) lives in the
/// LIGHTHOUSE registry / TIDE monitors; this struct is what the owner
/// declares when the island joins the mesh.
#[derive(Clone, Debug)]
pub struct Island {
    pub id: IslandId,
    pub name: String,
    pub tier: TrustTier,
    /// Round-trip base latency from the client in ms (`L_j`); netsim adds
    /// jitter and queueing on top.
    pub latency_ms: f64,
    /// Cost model (`C_j` derives from it).
    pub cost: CostModel,
    /// Privacy score `P_j` in [0,1], set by the island owner.
    pub privacy: f64,
    /// Trust components; composed via Eq. 2 into `T_j`.
    pub certification: Certification,
    pub jurisdiction: Jurisdiction,
    /// Max concurrent requests the island can execute (bounded islands).
    /// `None` = unbounded (Tier-3 HORIZON islands).
    pub capacity_slots: Option<usize>,
    /// Link class to the client.
    pub link: LinkKind,
    /// Battery fraction [0,1] for battery-powered islands (Scenario 2).
    pub battery: Option<f64>,
    /// Names of datasets / vector indices resident on this island
    /// (data-locality routing, §III.F).
    pub datasets: Vec<String>,
    /// Model variants this island can serve (heterogeneous model support).
    pub models: Vec<String>,
}

impl Island {
    /// Eq. 2 / §VII.C trust composition:
    /// `T_j = min(T_base, T_cert, T_jurisdiction)`.
    ///
    /// The paper gives both a `min` (§VII.C) and a product (Eq. 2) variant;
    /// `min` is the conservative default, the product variant is
    /// [`Island::trust_product`] (compared in eval E1 notes).
    pub fn trust(&self) -> f64 {
        self.tier
            .base_trust()
            .min(self.certification.score())
            .min(self.jurisdiction.score())
    }

    /// Eq. 2 product variant: `T_j = T_base * T_cert * T_jurisdiction`.
    pub fn trust_product(&self) -> f64 {
        self.tier.base_trust() * self.certification.score() * self.jurisdiction.score()
    }

    /// Marginal cost `C_j` for a request of `tokens` tokens.
    pub fn request_cost(&self, tokens: usize) -> f64 {
        self.cost.cost(tokens)
    }

    /// True when this island never exhausts (Tier-3 HORIZON).
    pub fn unbounded(&self) -> bool {
        self.capacity_slots.is_none()
    }

    /// Does this island hold the named dataset locally?
    pub fn has_dataset(&self, name: &str) -> bool {
        self.datasets.iter().any(|d| d == name)
    }

    /// §XIV heterogeneous model support: can this island serve `model`?
    pub fn serves_model(&self, model: &str) -> bool {
        self.models.iter().any(|m| m == model)
    }
}

/// Def. 2 request modality.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Modality {
    TextGeneration,
    CodeCompletion,
    ImageSynthesis,
    Embedding,
}

/// §IX.B priority tiers for workload classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PriorityTier {
    /// Mission-critical: must execute locally regardless of pressure.
    Primary,
    /// Important: prefers local, tolerates cloud when R < 50%.
    Secondary,
    /// Best-effort: local only when R > 80%, else cloud immediately.
    Burstable,
}

/// One turn of conversation history (`h_r` elements).
#[derive(Clone, Debug, PartialEq)]
pub struct Turn {
    pub role: Role,
    pub text: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    User,
    Assistant,
}

/// Def. 2 inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Originating user (rate limiting, Attack 4 mitigation).
    pub user: String,
    /// Input prompt `q`.
    pub prompt: String,
    pub modality: Modality,
    /// Sensitivity `s_r` in [0,1]; `None` until MIST scores it.
    pub sensitivity: Option<f64>,
    /// Maximum acceptable latency `d_r` (ms).
    pub deadline_ms: f64,
    /// Chat context history `h_r` for multi-turn conversations.
    pub history: Vec<Turn>,
    pub priority: PriorityTier,
    /// Dataset this request must run next to (data-locality, §III.F).
    pub required_dataset: Option<String>,
    /// Privacy tier of the island the *previous* turn executed on
    /// (`P_prev` in Algorithm 1 line 14); drives sanitize-on-transition.
    pub prev_island_privacy: Option<f64>,
    /// Max new tokens to generate.
    pub max_new_tokens: usize,
    /// §XIV heterogeneous model support: model family this request needs
    /// (e.g. "tinylm"); islands advertise what they serve.
    pub required_model: Option<String>,
    /// §XIV regulatory compliance: minimum jurisdiction score the serving
    /// island must declare (e.g. GDPR workloads require >= 0.9).
    pub min_jurisdiction: Option<f64>,
}

impl Request {
    /// A fresh single-turn request with sane defaults; builder-style setters
    /// below refine it.
    pub fn new(id: u64, prompt: &str) -> Request {
        Request {
            id,
            user: "user".to_string(),
            prompt: prompt.to_string(),
            modality: Modality::TextGeneration,
            sensitivity: None,
            deadline_ms: 2000.0,
            history: Vec::new(),
            priority: PriorityTier::Secondary,
            required_dataset: None,
            prev_island_privacy: None,
            max_new_tokens: 16,
            required_model: None,
            min_jurisdiction: None,
        }
    }

    pub fn with_user(mut self, user: &str) -> Self {
        self.user = user.to_string();
        self
    }
    pub fn with_priority(mut self, p: PriorityTier) -> Self {
        self.priority = p;
        self
    }
    pub fn with_deadline(mut self, ms: f64) -> Self {
        self.deadline_ms = ms;
        self
    }
    pub fn with_dataset(mut self, d: &str) -> Self {
        self.required_dataset = Some(d.to_string());
        self
    }
    pub fn with_history(mut self, h: Vec<Turn>) -> Self {
        self.history = h;
        self
    }
    pub fn with_sensitivity(mut self, s: f64) -> Self {
        self.sensitivity = Some(s);
        self
    }
    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n;
        self
    }
    pub fn with_model(mut self, m: &str) -> Self {
        self.required_model = Some(m.to_string());
        self
    }
    pub fn with_min_jurisdiction(mut self, j: f64) -> Self {
        self.min_jurisdiction = Some(j);
        self
    }

    /// Prompt + history tokens (the prefill side), estimated as
    /// ceil(chars / 4). Character-based on purpose: byte lengths over-charge
    /// multi-byte UTF-8 text 2-4x (a CJK prompt is not 3x the tokens of an
    /// ASCII one of the same length).
    pub fn prefill_token_estimate(&self) -> usize {
        let chars: usize =
            self.prompt.chars().count() + self.history.iter().map(|t| t.text.chars().count()).sum::<usize>();
        (chars + 3) / 4
    }

    /// Total token estimate (prefill + generation budget) for cost accounting.
    pub fn token_estimate(&self) -> usize {
        self.prefill_token_estimate() + self.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn island(tier: TrustTier, cert: Certification, jur: Jurisdiction) -> Island {
        Island {
            id: IslandId(0),
            name: "t".into(),
            tier,
            latency_ms: 10.0,
            cost: CostModel::Free,
            privacy: 1.0,
            certification: cert,
            jurisdiction: jur,
            capacity_slots: Some(2),
            link: LinkKind::Loopback,
            battery: None,
            datasets: vec!["case_law".into()],
            models: vec!["tinylm".into()],
        }
    }

    #[test]
    fn trust_min_composition_is_conservative() {
        // §VII.C: an island cannot claim high trust without meeting ALL criteria
        let i = island(TrustTier::Personal, Certification::SelfCertified, Jurisdiction::SameCountry);
        assert_eq!(i.trust(), 0.7); // limited by self-certification
        let i = island(TrustTier::Cloud, Certification::Iso27001, Jurisdiction::SameCountry);
        assert_eq!(i.trust(), 0.5); // limited by tier
        let i = island(TrustTier::PrivateEdge, Certification::Iso27001, Jurisdiction::Foreign);
        assert_eq!(i.trust(), 0.6); // limited by jurisdiction
    }

    #[test]
    fn trust_product_le_min() {
        for tier in [TrustTier::Personal, TrustTier::PrivateEdge, TrustTier::Cloud] {
            for cert in [Certification::Iso27001, Certification::Soc2, Certification::SelfCertified] {
                for jur in [Jurisdiction::SameCountry, Jurisdiction::EuGdpr, Jurisdiction::Foreign] {
                    let i = island(tier, cert, jur);
                    assert!(i.trust_product() <= i.trust() + 1e-12);
                }
            }
        }
    }

    #[test]
    fn tier_base_trust_matches_paper() {
        assert_eq!(TrustTier::Personal.base_trust(), 1.0);
        assert_eq!(TrustTier::PrivateEdge.base_trust(), 0.8);
        assert_eq!(TrustTier::Cloud.base_trust(), 0.5);
    }

    #[test]
    fn cost_models() {
        assert_eq!(CostModel::Free.cost(1000), 0.0);
        assert_eq!(CostModel::Fixed(0.001).cost(1000), 0.001);
        // per-request scales with tokens relative to the 64-token reference
        assert!((CostModel::PerRequest(0.02).cost(128) - 0.04).abs() < 1e-12);
        assert!(CostModel::PerRequest(0.02).cost(0) > 0.0); // min 1 token
    }

    #[test]
    fn dataset_lookup() {
        let i = island(TrustTier::Personal, Certification::Iso27001, Jurisdiction::SameCountry);
        assert!(i.has_dataset("case_law"));
        assert!(!i.has_dataset("phi_db"));
    }

    #[test]
    fn request_builder_and_tokens() {
        let r = Request::new(1, "hello world, this is a prompt")
            .with_user("alice")
            .with_priority(PriorityTier::Primary)
            .with_deadline(500.0)
            .with_dataset("case_law")
            .with_max_new_tokens(8);
        assert_eq!(r.user, "alice");
        assert_eq!(r.priority, PriorityTier::Primary);
        assert_eq!(r.deadline_ms, 500.0);
        assert_eq!(r.required_dataset.as_deref(), Some("case_law"));
        assert!(r.token_estimate() >= 8);
    }

    #[test]
    fn token_estimate_ascii_cjk_parity() {
        // 40 characters of ASCII and 40 characters of CJK must estimate the
        // same token count; the old byte-based estimate charged the CJK
        // prompt 3x (UTF-8 encodes each of these chars as 3 bytes).
        let ascii = Request::new(1, &"a".repeat(40)).with_max_new_tokens(8);
        let cjk = Request::new(2, &"\u{6f22}".repeat(40)).with_max_new_tokens(8);
        assert_eq!(ascii.prefill_token_estimate(), 10); // ceil(40 / 4)
        assert_eq!(cjk.prefill_token_estimate(), ascii.prefill_token_estimate());
        assert_eq!(cjk.token_estimate(), ascii.token_estimate());
        // ceil, not floor: a 1-char prompt is still >= 1 prefill token
        assert_eq!(Request::new(3, "x").prefill_token_estimate(), 1);
        // history counts toward prefill
        let with_hist = Request::new(4, &"a".repeat(40))
            .with_history(vec![Turn { role: Role::User, text: "\u{6f22}".repeat(40) }]);
        assert_eq!(with_hist.prefill_token_estimate(), 20);
    }

    #[test]
    fn priority_ordering() {
        assert!(PriorityTier::Primary < PriorityTier::Secondary);
        assert!(PriorityTier::Secondary < PriorityTier::Burstable);
    }
}
