//! # IslandRun — privacy-aware multi-objective orchestration for distributed AI inference
//!
//! Reproduction of *IslandRun: Privacy-Aware Multi-Objective Orchestration
//! for Distributed AI Inference* (CS.DC 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the paper's coordination contribution: the WAVES
//!   multi-objective router (Algorithm 1 / Eq. 1), MIST sensitivity scoring +
//!   typed-placeholder sanitization (Def. 4), TIDE resource monitoring
//!   (Eq. 3, hysteresis, tiered prompt routing), LIGHTHOUSE mesh/registry
//!   (trust composition Eq. 2, heartbeats), SHORE/HORIZON island executors,
//!   session store, rate limiting, baselines and the full evaluation harness.
//! - **L2** — JAX models (TinyLM, MIST Stage-2 classifier, embedder) in
//!   `python/compile/`, AOT-lowered once to HLO text.
//! - **L1** — Pallas kernels (tiled causal attention, fused MLP) in
//!   `python/compile/kernels/`, verified against pure-jnp oracles.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO text
//! artifacts through the PJRT CPU client (`xla` crate) and serves them from
//! rust. See `DESIGN.md` for the full system inventory and the
//! per-experiment index (E1–E13), and `EXPERIMENTS.md` for results.

pub mod agents;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod eval;
pub mod islands;
pub mod runtime;
pub mod security;
pub mod server;
pub mod substrate;
pub mod telemetry;
pub mod types;
pub mod util;

pub use types::{Island, IslandId, Modality, PriorityTier, Request, TrustTier};
