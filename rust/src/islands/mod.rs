//! Execution endpoints: SHORE (local), private edge and HORIZON (cloud).
//!
//! Two execution paths share the same island specs:
//! - [`sim`] — virtual-time simulator used by the eval harness and benches
//!   (10k-request experiments finish in milliseconds; latency calibrated to
//!   the paper's §XI.B bands),
//! - [`executor`] — the real serving path: PJRT TinyLM inference through
//!   [`crate::runtime::Engine`], with netsim link delays accounted per
//!   island (quickstart / examples / e2e bench).
//!
//! [`cost`] is the per-user spend ledger (cost agent substrate).

pub mod cost;
pub mod executor;
pub mod sim;

pub use cost::CostLedger;
pub use sim::{DecodeHandle, ExecContext, ExecError, ExecReport, Fleet, SimIsland};
