//! Real serving path: SHORE / edge / HORIZON executors over the PJRT engine.
//!
//! Every island runs the same AOT TinyLM artifact (one compiled executable
//! per batch variant, shared through the engine thread); what differs per
//! island is the *network* (simulated link delay charged to the request) and
//! the *price*. This mirrors the deployment substitution recorded in
//! DESIGN.md §2: routing behavior depends on the (L, C, P, T, R) tuple, not
//! on which physical box held the weights.

use std::sync::Mutex;

use crate::runtime::EngineHandle;
use crate::substrate::netsim::NetSim;
use crate::types::{Island, IslandId, Request};

use crate::util::sync::LockExt;

/// A completed inference with full accounting.
#[derive(Clone, Debug)]
pub struct Response {
    pub island: IslandId,
    pub text: String,
    pub tokens_generated: usize,
    /// PJRT compute milliseconds.
    pub compute_ms: f64,
    /// Simulated network round-trip milliseconds.
    pub network_ms: f64,
    pub cost: f64,
}

/// Marker prefix for island-down execution errors. The orchestrator's
/// failover path matches on this to distinguish "this island is
/// unreachable, re-route to the next Pareto candidate" from fatal engine
/// errors that no amount of re-routing fixes.
const ISLAND_DOWN_PREFIX: &str = "island-down:";

/// Build an island-down error (link dead after retries / island gone).
pub fn island_down_error(id: IslandId) -> anyhow::Error {
    anyhow::anyhow!("{ISLAND_DOWN_PREFIX} island {id} unreachable")
}

/// Does this execution error mean the island itself is down (re-routable)?
pub fn is_island_down(err: &anyhow::Error) -> bool {
    err.to_string().starts_with(ISLAND_DOWN_PREFIX)
}

/// Executes requests on islands through the shared engine.
pub struct IslandExecutor {
    engine: EngineHandle,
    net: Mutex<NetSim>,
}

impl IslandExecutor {
    pub fn new(engine: EngineHandle, seed: u64) -> IslandExecutor {
        IslandExecutor { engine, net: Mutex::new(NetSim::new(seed)) }
    }

    /// Run one request on `island` (single-prompt path).
    pub fn execute(&self, island: &Island, request: &Request) -> anyhow::Result<Response> {
        let mut results = self.execute_batch(island, std::slice::from_ref(request))?;
        results.pop().ok_or_else(|| anyhow::anyhow!("island {} returned no response for the request", island.id))
    }

    /// Run a batch of requests on the same island (dynamic batcher output).
    pub fn execute_batch(&self, island: &Island, requests: &[Request]) -> anyhow::Result<Vec<Response>> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let prompts: Vec<String> = requests
            .iter()
            .map(|r| {
                // history travels with the request (already sanitized by the
                // server when crossing trust boundaries)
                let mut p = String::new();
                for t in &r.history {
                    p.push_str(&t.text);
                    p.push('\n');
                }
                p.push_str(&r.prompt);
                p
            })
            .collect();
        let max_new = requests.iter().map(|r| r.max_new_tokens).max().unwrap_or(16);
        let gens = self.engine.generate(prompts, max_new)?;

        let mut out = Vec::with_capacity(requests.len());
        for (req, gen) in requests.iter().zip(gens) {
            let payload_kb = (req.prompt.len() + req.max_new_tokens) as f64 / 1024.0;
            // a link that fails every retry means the island is unreachable:
            // surface it as an island-down error so the orchestrator fails
            // over instead of charging the user for a request that never ran
            let network_ms = {
                let mut net = self.net.lock_clean();
                net.round_trip_retry(island.link, payload_kb.max(0.5), 3).ok_or_else(|| island_down_error(island.id))?
            };
            out.push(Response {
                island: island.id,
                text: gen.text,
                tokens_generated: gen.tokens_generated,
                compute_ms: gen.compute_ms,
                network_ms,
                cost: island.request_cost(req.token_estimate()),
            });
        }
        Ok(out)
    }
}

// Integration coverage (needs artifacts): rust/tests/integration_e2e.rs and
// examples/quickstart.rs. Unit tests below cover the prompt assembly logic.
#[cfg(test)]
mod tests {
    use super::{is_island_down, island_down_error};
    use crate::types::IslandId;
    use crate::types::{Role, Turn};

    #[test]
    fn island_down_errors_are_classifiable() {
        let e = island_down_error(IslandId(3));
        assert!(is_island_down(&e), "{e}");
        assert!(!is_island_down(&anyhow::anyhow!("engine OOM")));
    }

    #[test]
    fn history_precedes_prompt_in_framing() {
        // The framing rule lives in execute_batch; assert the same joining
        // logic used there.
        let history = vec![
            Turn { role: Role::User, text: "first turn".into() },
            Turn { role: Role::Assistant, text: "reply".into() },
        ];
        let mut p = String::new();
        for t in &history {
            p.push_str(&t.text);
            p.push('\n');
        }
        p.push_str("the prompt");
        assert_eq!(p, "first turn\nreply\nthe prompt");
    }
}
