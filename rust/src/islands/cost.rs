//! Per-user spend ledger — the cost agent's substrate (§I.C agent 3:
//! "Track per-request billing and enforce budget ceilings").
//!
//! Thread-safe: the running total is an atomic `f64`, per-user balances are
//! sharded by user-name hash so concurrent submitters on different users
//! rarely contend on the same lock.

use std::collections::BTreeMap;
use std::sync::RwLock;

use crate::runtime::features::fnv1a;
use crate::util::AtomicF64;

use crate::util::sync::RwLockExt;

const SHARDS: usize = 8;

fn shard_of(user: &str) -> usize {
    (fnv1a(user.as_bytes()) % SHARDS as u64) as usize
}

/// Tracks dollars spent per user and enforces a ceiling.
#[derive(Debug, Default)]
pub struct CostLedger {
    shards: [RwLock<BTreeMap<String, f64>>; SHARDS],
    total: AtomicF64,
}

impl CostLedger {
    pub fn new() -> CostLedger {
        CostLedger::default()
    }

    /// Record a charge.
    pub fn charge(&self, user: &str, amount: f64) {
        let mut shard = self.shards[shard_of(user)].write_clean();
        *shard.entry(user.to_string()).or_insert(0.0) += amount;
        self.total.fetch_add(amount);
    }

    pub fn spent(&self, user: &str) -> f64 {
        self.shards[shard_of(user)].read_clean().get(user).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.total.load()
    }

    /// Remaining budget for a user under `ceiling` (never negative).
    pub fn remaining(&self, user: &str, ceiling: f64) -> f64 {
        (ceiling - self.spent(user)).max(0.0)
    }

    /// Users sorted by spend (reporting).
    pub fn by_user(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = Vec::new();
        for shard in &self.shards {
            v.extend(shard.read_clean().iter().map(|(k, &x)| (k.clone(), x)));
        }
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate_per_user() {
        let l = CostLedger::new();
        l.charge("alice", 0.02);
        l.charge("alice", 0.03);
        l.charge("bob", 0.01);
        assert!((l.spent("alice") - 0.05).abs() < 1e-12);
        assert!((l.total() - 0.06).abs() < 1e-12);
        assert_eq!(l.spent("carol"), 0.0);
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let l = CostLedger::new();
        l.charge("alice", 5.0);
        assert_eq!(l.remaining("alice", 10.0), 5.0);
        assert_eq!(l.remaining("alice", 3.0), 0.0);
    }

    #[test]
    fn by_user_sorted_descending() {
        let l = CostLedger::new();
        l.charge("a", 0.1);
        l.charge("b", 0.5);
        l.charge("c", 0.3);
        let v = l.by_user();
        assert_eq!(v[0].0, "b");
        assert_eq!(v[2].0, "a");
    }

    #[test]
    fn concurrent_charges_are_not_lost() {
        use std::sync::Arc;
        let l = Arc::new(CostLedger::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let l = Arc::clone(&l);
                std::thread::spawn(move || {
                    let user = format!("user-{t}");
                    for _ in 0..500 {
                        l.charge(&user, 0.25); // exact in f64
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..8 {
            assert_eq!(l.spent(&format!("user-{t}")), 125.0);
        }
        assert_eq!(l.total(), 1000.0);
    }
}
