//! Per-user spend ledger — the cost agent's substrate (§I.C agent 3:
//! "Track per-request billing and enforce budget ceilings").

use std::collections::BTreeMap;

/// Tracks dollars spent per user and enforces a ceiling.
#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    spent: BTreeMap<String, f64>,
    total: f64,
}

impl CostLedger {
    pub fn new() -> CostLedger {
        CostLedger::default()
    }

    /// Record a charge.
    pub fn charge(&mut self, user: &str, amount: f64) {
        *self.spent.entry(user.to_string()).or_insert(0.0) += amount;
        self.total += amount;
    }

    pub fn spent(&self, user: &str) -> f64 {
        self.spent.get(user).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    /// Remaining budget for a user under `ceiling` (never negative).
    pub fn remaining(&self, user: &str, ceiling: f64) -> f64 {
        (ceiling - self.spent(user)).max(0.0)
    }

    /// Users sorted by spend (reporting).
    pub fn by_user(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self.spent.iter().map(|(k, &v)| (k.clone(), v)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate_per_user() {
        let mut l = CostLedger::new();
        l.charge("alice", 0.02);
        l.charge("alice", 0.03);
        l.charge("bob", 0.01);
        assert!((l.spent("alice") - 0.05).abs() < 1e-12);
        assert!((l.total() - 0.06).abs() < 1e-12);
        assert_eq!(l.spent("carol"), 0.0);
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut l = CostLedger::new();
        l.charge("alice", 5.0);
        assert_eq!(l.remaining("alice", 10.0), 5.0);
        assert_eq!(l.remaining("alice", 3.0), 0.0);
    }

    #[test]
    fn by_user_sorted_descending() {
        let mut l = CostLedger::new();
        l.charge("a", 0.1);
        l.charge("b", 0.5);
        l.charge("c", 0.3);
        let v = l.by_user();
        assert_eq!(v[0].0, "b");
        assert_eq!(v[2].0, "a");
    }
}
