//! Virtual-time island simulator.
//!
//! Models each island as a set of execution slots plus an optional external
//! load program; requests experience network RTT ([`crate::substrate::netsim`]),
//! queueing (earliest free slot) and compute time. Compute-time constants
//! are calibrated so end-to-end latencies land in the paper's §XI.B bands:
//!
//!   personal: 50–500 ms  · private edge: 100–1000 ms · cloud: 200–2000 ms
//!
//! (validated by eval E4 and integration tests). Unbounded (Tier-3) islands
//! never queue — HORIZON "scales to thousands of concurrent requests" — but
//! pay WAN latency and per-request cost.
//!
//! Concurrency: the fleet is shared behind `Arc<Orchestrator>`, so the
//! virtual clock is an atomic f64 and each island's runtime state (slots,
//! battery, external load) sits behind its own mutex — submitters routed to
//! different islands never contend, and WAVES admission reads capacity
//! without blocking writers for long.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::substrate::netsim::NetSim;
use crate::types::{Island, IslandId, Request, TrustTier};
use crate::util::AtomicF64;

use crate::util::sync::{LockExt, RwLockExt};

/// Per-tier compute model: fixed startup + per-token milliseconds.
fn compute_model(tier: TrustTier) -> (f64, f64) {
    match tier {
        // (startup_ms, per_token_ms)
        TrustTier::Personal => (30.0, 4.0),
        TrustTier::PrivateEdge => (50.0, 2.0),
        TrustTier::Cloud => (90.0, 1.2),
    }
}

/// Payload a request moves over the network: prompt + history out, generated
/// tokens back (KB) — E11 accounting.
fn payload_kb(request: &Request) -> f64 {
    (request.prompt.len() + request.history.iter().map(|t| t.text.len()).sum::<usize>() + request.max_new_tokens)
        as f64
        / 1024.0
}

/// Why a simulated execution could not run. The distinction matters to the
/// orchestrator's failover path: both variants mean "this island cannot
/// serve the request right now" and trigger a re-route, but they are audited
/// with different reasons.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// No island with this id is in the fleet (it left, or never joined).
    UnknownIsland(IslandId),
    /// The island is present but crashed / powered off.
    IslandDown(IslandId),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::UnknownIsland(id) => write!(f, "island {id} not in fleet"),
            ExecError::IslandDown(id) => write!(f, "island {id} is offline"),
        }
    }
}

impl std::error::Error for ExecError {}

/// Typed execution context: everything the fleet samples on a request's
/// behalf before an island runs it. Replaces the old grab-bag of floats
/// (`now_ms`, `rtt`, `payload_kb`) so the one-shot [`SimIsland::execute`]
/// and the [`SimIsland::prefill`] / [`SimIsland::decode_step`] pair share
/// one signature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecContext {
    /// Virtual arrival time (ms).
    pub now_ms: f64,
    /// Pre-sampled network round trip for this request's payload (ms).
    pub rtt_ms: f64,
    /// Bytes moved over the network (KB) — E11 accounting.
    pub payload_kb: f64,
}

/// An in-flight decode: returned by [`SimIsland::prefill`], advanced by
/// [`SimIsland::decode_step`]. The handle owns the request's position in
/// virtual time (`cursor_ms`) and its running cost; the island's slot is
/// only ever booked through the last *completed* step, so dropping a handle
/// mid-decode frees the slot immediately — nothing to un-book.
#[derive(Clone, Debug)]
pub struct DecodeHandle {
    island: IslandId,
    /// Booked slot index on bounded islands (`None` = unbounded).
    slot: Option<usize>,
    /// Virtual time through which this request has computed.
    cursor_ms: f64,
    arrival_ms: f64,
    queued_ms: f64,
    rtt_ms: f64,
    payload_kb: f64,
    /// Per-token decode cost in ms, slowdown-adjusted at prefill time.
    per_token_ms: f64,
    prefill_tokens: usize,
    max_new_tokens: usize,
    tokens_decoded: usize,
    /// Running cost: prefill + tokens decoded so far.
    cost: f64,
}

impl DecodeHandle {
    pub fn island(&self) -> IslandId {
        self.island
    }

    pub fn tokens_decoded(&self) -> usize {
        self.tokens_decoded
    }

    /// Has the full `max_new_tokens` budget been decoded?
    pub fn is_complete(&self) -> bool {
        self.tokens_decoded >= self.max_new_tokens
    }

    /// Virtual time through which this request has computed (prefill end +
    /// completed decode steps). The caller's deadline checks compare this
    /// against the request's absolute deadline.
    pub fn cursor_ms(&self) -> f64 {
        self.cursor_ms
    }

    pub fn cost(&self) -> f64 {
        self.cost
    }

    /// Report for the work done so far (complete or cancelled): latency
    /// covers network + queue + prefill + completed decode steps, cost
    /// covers only tokens actually decoded.
    pub fn report(&self) -> ExecReport {
        ExecReport {
            island: self.island,
            arrival_ms: self.arrival_ms,
            latency_ms: self.cursor_ms + self.rtt_ms / 2.0 - self.arrival_ms,
            queued_ms: self.queued_ms,
            cost: self.cost,
            payload_kb: self.payload_kb,
        }
    }
}

/// Outcome of one simulated execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecReport {
    pub island: IslandId,
    /// Virtual arrival time (ms).
    pub arrival_ms: f64,
    /// Total request latency: network + queue + compute (ms).
    pub latency_ms: f64,
    /// Time spent queued for a slot (ms).
    pub queued_ms: f64,
    /// Dollar cost charged.
    pub cost: f64,
    /// Bytes moved over the network (KB) — E11 accounting.
    pub payload_kb: f64,
}

/// Mutable runtime state of one island, guarded per island.
#[derive(Debug)]
struct IslandRt {
    /// Virtual time when each slot frees up (bounded islands).
    busy_until: Vec<f64>,
    /// External utilization in [0,1) (0 = idle), added on top of slot usage.
    external_load: f64,
    /// Remaining battery fraction for battery-powered islands.
    battery: Option<f64>,
    /// Total requests executed (telemetry).
    executed: u64,
}

/// One simulated island.
#[derive(Debug)]
pub struct SimIsland {
    pub spec: Island,
    rt: Mutex<IslandRt>,
    /// Power state: `false` = crashed / powered off. Flipped by
    /// [`Fleet::crash`] / [`Fleet::revive`] from churn drivers; an offline
    /// island reports zero capacity and refuses execution.
    online: AtomicBool,
}

impl SimIsland {
    pub fn new(spec: Island) -> SimIsland {
        let slots = spec.capacity_slots.unwrap_or(0);
        let battery = spec.battery;
        SimIsland {
            spec,
            rt: Mutex::new(IslandRt { busy_until: vec![0.0; slots], external_load: 0.0, battery, executed: 0 }),
            online: AtomicBool::new(true),
        }
    }

    pub fn is_online(&self) -> bool {
        self.online.load(Ordering::SeqCst)
    }

    pub fn set_online(&self, online: bool) {
        self.online.store(online, Ordering::SeqCst);
    }

    /// Available capacity R_j(t): fraction of free slots, reduced by the
    /// external load program. Unbounded islands always report 1.0; offline
    /// islands always report 0.0.
    pub fn capacity(&self, now_ms: f64) -> f64 {
        if !self.is_online() {
            return 0.0;
        }
        if self.spec.unbounded() {
            return 1.0;
        }
        let rt = self.rt.lock_clean();
        if rt.busy_until.is_empty() {
            return 0.0;
        }
        let free = rt.busy_until.iter().filter(|&&t| t <= now_ms).count() as f64;
        let slot_cap = free / rt.busy_until.len() as f64;
        (slot_cap * (1.0 - rt.external_load)).clamp(0.0, 1.0)
    }

    /// Set the external utilization knob (load programs / test scaffolding).
    pub fn set_external_load(&self, load: f64) {
        self.rt.lock_clean().external_load = load;
    }

    pub fn external_load(&self) -> f64 {
        self.rt.lock_clean().external_load
    }

    /// Current battery fraction, if battery-powered.
    pub fn battery(&self) -> Option<f64> {
        self.rt.lock_clean().battery
    }

    /// Total requests this island has executed.
    pub fn executed(&self) -> u64 {
        self.rt.lock_clean().executed
    }

    /// Run the prefill phase: book the earliest free slot, charge compute
    /// for the prompt + history tokens, and return a [`DecodeHandle`]
    /// positioned at the prefill's end. The caller has already decided this
    /// island is the target (router) and sampled the link
    /// ([`Fleet::prefill`] does both).
    pub fn prefill(&self, request: &Request, ctx: ExecContext) -> Result<DecodeHandle, ExecError> {
        let mut rt = self.rt.lock_clean();
        // checked under the rt lock so a crash() racing this call is seen
        // before any slot is booked
        if !self.is_online() {
            return Err(ExecError::IslandDown(self.spec.id));
        }
        let (startup, per_token) = compute_model(self.spec.tier);
        // external load slows compute proportionally; frozen at prefill
        // time so every decode step of this request prices consistently
        let slow = 1.0 / (1.0 - rt.external_load.min(0.9));
        let prefill_tokens = request.prefill_token_estimate();
        let prefill_ms = (startup + per_token * prefill_tokens as f64) * slow;

        let (slot, queued, start) = if self.spec.unbounded() {
            (None, 0.0, ctx.now_ms + ctx.rtt_ms / 2.0)
        } else {
            // earliest-free-slot queueing. A bounded island always has at
            // least one slot; treat a zero-slot spec as permanently busy
            // from `now` rather than panicking mid-request.
            let (slot_idx, free_at) = rt
                .busy_until
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, &t)| (i, t))
                .unwrap_or((0, ctx.now_ms));
            let start = (ctx.now_ms + ctx.rtt_ms / 2.0).max(free_at);
            let queued = (free_at - (ctx.now_ms + ctx.rtt_ms / 2.0)).max(0.0);
            if let Some(slot) = rt.busy_until.get_mut(slot_idx) {
                *slot = start + prefill_ms;
            }
            (Some(slot_idx), queued, start)
        };

        // battery drain: proportional to compute on battery islands
        if let Some(b) = rt.battery.as_mut() {
            *b = (*b - prefill_ms / 2_000_000.0).max(0.0);
        }
        rt.executed += 1;

        Ok(DecodeHandle {
            island: self.spec.id,
            slot,
            cursor_ms: start + prefill_ms,
            arrival_ms: ctx.now_ms,
            queued_ms: queued,
            rtt_ms: ctx.rtt_ms,
            payload_kb: ctx.payload_kb,
            per_token_ms: per_token * slow,
            prefill_tokens,
            max_new_tokens: request.max_new_tokens,
            tokens_decoded: 0,
            cost: self.spec.request_cost(prefill_tokens),
        })
    }

    /// Decode up to `max_tokens` further tokens (capped by the handle's
    /// remaining budget), extending the slot booking by exactly the step's
    /// compute. Returns the number of tokens decoded this step (0 when the
    /// budget is exhausted). Between steps the slot is only booked through
    /// completed work, so a caller that stops stepping frees the island
    /// immediately — that is the cancel path.
    pub fn decode_step(&self, h: &mut DecodeHandle, max_tokens: usize) -> Result<usize, ExecError> {
        let n = max_tokens.min(h.max_new_tokens.saturating_sub(h.tokens_decoded));
        if n == 0 {
            return Ok(0);
        }
        let mut rt = self.rt.lock_clean();
        if !self.is_online() {
            return Err(ExecError::IslandDown(self.spec.id));
        }
        let step_ms = h.per_token_ms * n as f64;
        // a co-resident request may have booked our slot past our cursor
        // since the last step: decode resumes at whichever is later, so
        // slot bookings stay monotone and requests time-share the slot
        let start = match h.slot {
            Some(s) => h.cursor_ms.max(rt.busy_until.get(s).copied().unwrap_or(h.cursor_ms)),
            None => h.cursor_ms,
        };
        if let Some(s) = h.slot {
            if let Some(b) = rt.busy_until.get_mut(s) {
                *b = start + step_ms;
            }
        }
        h.cursor_ms = start + step_ms;
        h.tokens_decoded += n;
        h.cost = self.spec.request_cost(h.prefill_tokens + h.tokens_decoded);
        if let Some(b) = rt.battery.as_mut() {
            *b = (*b - step_ms / 2_000_000.0).max(0.0);
        }
        Ok(n)
    }

    /// Legacy one-shot execution: prefill plus the full decode budget in a
    /// single call. Mathematically identical to the pre-split path (same
    /// total compute, slot booking, battery drain and cost); the blocking
    /// submit path and the coalescing batcher still use it.
    pub fn execute(&self, request: &Request, ctx: ExecContext) -> Result<ExecReport, ExecError> {
        let mut handle = self.prefill(request, ctx)?;
        self.decode_step(&mut handle, request.max_new_tokens)?;
        Ok(handle.report())
    }
}

/// A mesh of simulated islands sharing a virtual clock.
///
/// Membership is dynamic: islands [`crash`](Fleet::crash) and
/// [`revive`](Fleet::revive) in place (power state), and
/// [`join`](Fleet::join) / [`leave`](Fleet::leave) the mesh entirely — all
/// through `&self`, so churn drivers (tests, the load generator's churn
/// thread) run concurrently with submitters. The island list sits behind an
/// `RwLock` of `Arc`s: the hot path takes a read lock just long enough to
/// clone the target's `Arc`, then executes against the island's own mutex.
#[derive(Debug)]
pub struct Fleet {
    islands: RwLock<Vec<Arc<SimIsland>>>,
    net: Mutex<NetSim>,
    now_ms: AtomicF64,
}

impl Fleet {
    pub fn new(specs: Vec<Island>, seed: u64) -> Fleet {
        Fleet {
            islands: RwLock::new(specs.into_iter().map(|s| Arc::new(SimIsland::new(s))).collect()),
            net: Mutex::new(NetSim::new(seed)),
            now_ms: AtomicF64::new(0.0),
        }
    }

    pub fn now(&self) -> f64 {
        self.now_ms.load()
    }

    /// Advance the virtual clock (atomic; callable from any thread).
    pub fn advance(&self, dt_ms: f64) {
        self.now_ms.fetch_add(dt_ms);
    }

    /// Snapshot of the current island list (membership may change the
    /// moment the read lock drops; the `Arc`s stay valid regardless).
    pub fn islands(&self) -> Vec<Arc<SimIsland>> {
        self.islands.read_clean().clone()
    }

    /// Current island specs (registration / discovery view).
    pub fn specs(&self) -> Vec<Island> {
        self.islands.read_clean().iter().map(|i| i.spec.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.islands.read_clean().len()
    }

    pub fn is_empty(&self) -> bool {
        self.islands.read_clean().is_empty()
    }

    pub fn get(&self, id: IslandId) -> Option<Arc<SimIsland>> {
        self.islands.read_clean().iter().find(|i| i.spec.id == id).cloned()
    }

    /// Power an island off in place (it stays a fleet member: heartbeats
    /// stop, capacity reads 0, execution fails island-down). Returns false
    /// for unknown ids.
    pub fn crash(&self, id: IslandId) -> bool {
        match self.get(id) {
            Some(island) => {
                island.set_online(false);
                true
            }
            None => false,
        }
    }

    /// Power a crashed island back on. Returns false for unknown ids.
    pub fn revive(&self, id: IslandId) -> bool {
        match self.get(id) {
            Some(island) => {
                island.set_online(true);
                true
            }
            None => false,
        }
    }

    /// Add a new island to the mesh (dynamic discovery). Rejects duplicate
    /// ids; the new island starts online with fresh runtime state.
    pub fn join(&self, spec: Island) -> bool {
        let mut islands = self.islands.write_clean();
        if islands.iter().any(|i| i.spec.id == spec.id) {
            return false;
        }
        islands.push(Arc::new(SimIsland::new(spec)));
        true
    }

    /// Remove an island from the mesh entirely (clean leave). In-flight
    /// executions holding the island's `Arc` complete; new requests see
    /// `UnknownIsland`.
    pub fn leave(&self, id: IslandId) -> Option<Island> {
        let mut islands = self.islands.write_clean();
        let pos = islands.iter().position(|i| i.spec.id == id)?;
        Some(islands.remove(pos).spec.clone())
    }

    /// Drop every island whose spec fails the predicate (test scaffolding).
    pub fn retain(&self, pred: impl Fn(&Island) -> bool) {
        self.islands.write_clean().retain(|i| pred(&i.spec));
    }

    /// Router-facing dynamic state snapshot.
    pub fn states(&self) -> Vec<crate::agents::waves::IslandState> {
        let now = self.now();
        self.islands
            .read_clean()
            .iter()
            .map(|i| crate::agents::waves::IslandState {
                island: i.spec.clone(),
                capacity: i.capacity(now),
                online: i.is_online(),
                // TIDE's degrade view is layered on by the orchestrator;
                // the raw fleet snapshot only knows power state
                degraded: false,
            })
            .collect()
    }

    /// TIDE's local view: mean capacity across the personal island group
    /// (the user's own devices — whichever of them is currently "local").
    pub fn local_capacity(&self) -> f64 {
        let now = self.now();
        let personal: Vec<f64> = self
            .islands
            .read_clean()
            .iter()
            .filter(|i| i.spec.tier == TrustTier::Personal)
            .map(|i| i.capacity(now))
            .collect();
        if personal.is_empty() {
            0.0
        } else {
            personal.iter().sum::<f64>() / personal.len() as f64
        }
    }

    /// Build the typed [`ExecContext`] for a request on `island`: current
    /// virtual time plus one RTT sample for the request's payload. Only the
    /// sample holds the shared NetSim lock.
    fn exec_context(&self, island: &SimIsland, request: &Request) -> ExecContext {
        let now_ms = self.now();
        let payload_kb = payload_kb(request);
        let rtt_ms = {
            let mut net = self.net.lock_clean();
            net.round_trip_retry(island.spec.link, payload_kb.max(0.5), 3).unwrap_or(5_000.0)
        };
        ExecContext { now_ms, rtt_ms, payload_kb }
    }

    /// Resolve an island for execution: present and online, or the error
    /// the orchestrator's failover path expects.
    fn live_island(&self, id: IslandId) -> Result<Arc<SimIsland>, ExecError> {
        let island = self.get(id).ok_or(ExecError::UnknownIsland(id))?;
        if !island.is_online() {
            return Err(ExecError::IslandDown(id));
        }
        Ok(island)
    }

    /// Execute on a chosen island at the current virtual time. Slot booking
    /// and accounting run under the target island's own mutex, so
    /// executions on different islands overlap. Fails island-down when the
    /// target crashed between routing and execution (the orchestrator's
    /// failover path re-routes).
    pub fn execute(&self, id: IslandId, request: &Request) -> Result<ExecReport, ExecError> {
        let island = self.live_island(id)?;
        let ctx = self.exec_context(&island, request);
        island.execute(request, ctx)
    }

    /// Start a request on a chosen island: prefill only, returning the
    /// [`DecodeHandle`] the per-island step loop advances between batch
    /// admissions.
    pub fn prefill(&self, id: IslandId, request: &Request) -> Result<DecodeHandle, ExecError> {
        let island = self.live_island(id)?;
        let ctx = self.exec_context(&island, request);
        island.prefill(request, ctx)
    }

    /// Advance an in-flight decode by up to `max_tokens` tokens. Fails
    /// island-down / unknown-island when the island crashed or left the
    /// fleet mid-decode (the step loop falls back to a re-routed one-shot).
    pub fn decode_step(&self, h: &mut DecodeHandle, max_tokens: usize) -> Result<usize, ExecError> {
        let island = self.live_island(h.island())?;
        island.decode_step(h, max_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn fleet() -> Fleet {
        Fleet::new(preset_personal_group(), 7)
    }

    #[test]
    fn latencies_fall_in_paper_bands() {
        // §XI.B: personal 50-500, edge 100-1000, cloud 200-2000 (ms)
        let f = fleet();
        let r = Request::new(1, &"x".repeat(200)).with_max_new_tokens(16);
        let mut check = |id: u32, lo: f64, hi: f64, name: &str| {
            let mut worst = (f64::INFINITY, 0.0f64);
            for _ in 0..50 {
                let rep = f.execute(IslandId(id), &r).unwrap();
                worst = (worst.0.min(rep.latency_ms), worst.1.max(rep.latency_ms));
                f.advance(10_000.0); // let slots clear
            }
            assert!(worst.0 >= lo * 0.5 && worst.1 <= hi * 1.5, "{name}: {worst:?} not near [{lo},{hi}]");
        };
        check(0, 50.0, 500.0, "laptop");
        check(4, 100.0, 1000.0, "edge");
        check(5, 200.0, 2000.0, "cloud");
    }

    #[test]
    fn bounded_islands_queue() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(32);
        // mobile has 1 slot: second request must queue
        let first = f.execute(IslandId(1), &r).unwrap();
        let second = f.execute(IslandId(1), &r).unwrap();
        assert_eq!(first.queued_ms, 0.0);
        assert!(second.queued_ms > 0.0, "{second:?}");
        assert!(second.latency_ms > first.latency_ms);
    }

    #[test]
    fn unbounded_cloud_never_queues() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        for _ in 0..100 {
            let rep = f.execute(IslandId(5), &r).unwrap();
            assert_eq!(rep.queued_ms, 0.0);
        }
    }

    #[test]
    fn capacity_reflects_slot_usage_and_recovers() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(64);
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(0.0), 1.0);
        for _ in 0..4 {
            f.execute(IslandId(0), &r).unwrap();
        }
        // laptop saturated; group mean reflects 3 idle devices
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(f.now()), 0.0);
        assert!(f.local_capacity() < 0.8);
        f.advance(60_000.0);
        assert_eq!(f.local_capacity(), 1.0);
    }

    #[test]
    fn external_load_reduces_capacity_and_slows_compute() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(16);
        let fast = f.execute(IslandId(0), &r).unwrap();
        f.advance(60_000.0);
        f.get(IslandId(0)).unwrap().set_external_load(0.8);
        assert!(f.get(IslandId(0)).unwrap().capacity(f.now()) <= 0.2);
        let slow = f.execute(IslandId(0), &r).unwrap();
        assert!(slow.latency_ms > 2.0 * fast.latency_ms, "fast={fast:?} slow={slow:?}");
    }

    #[test]
    fn cloud_charges_money_local_is_free() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        assert_eq!(f.execute(IslandId(0), &r).unwrap().cost, 0.0);
        assert!(f.execute(IslandId(5), &r).unwrap().cost > 0.0);
    }

    #[test]
    fn battery_drains_with_use() {
        let f = fleet();
        let before = f.get(IslandId(1)).unwrap().battery().unwrap();
        let r = Request::new(1, "prompt").with_max_new_tokens(64);
        for _ in 0..20 {
            f.execute(IslandId(1), &r).unwrap();
            f.advance(10_000.0);
        }
        let after = f.get(IslandId(1)).unwrap().battery().unwrap();
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn states_snapshot_matches_islands() {
        let f = fleet();
        let st = f.states();
        assert_eq!(st.len(), 7);
        assert!(st.iter().all(|s| (0.0..=1.0).contains(&s.capacity)));
    }

    #[test]
    fn concurrent_executes_account_every_request() {
        use std::sync::Arc;
        let f = Arc::new(fleet());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    let r = Request::new(t, "prompt");
                    for _ in 0..50 {
                        // mix a bounded and an unbounded island
                        f.execute(IslandId((t % 2 * 5) as u32), &r).unwrap();
                        f.advance(100.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: u64 = f.islands().iter().map(|i| i.executed()).sum();
        assert_eq!(total, 400);
        assert!((f.now() - 40_000.0).abs() < 1e-6);
    }

    #[test]
    fn prefill_plus_steps_matches_one_shot_execute() {
        // same seed → same RTT sample sequence: stepping the decode in
        // chunks must land on exactly the report the one-shot path produces
        let a = fleet();
        let b = fleet();
        let r = Request::new(1, &"x".repeat(200)).with_max_new_tokens(16);
        for id in [0u32, 1, 5] {
            let one_shot = a.execute(IslandId(id), &r).unwrap();
            let mut h = b.prefill(IslandId(id), &r).unwrap();
            assert_eq!(h.tokens_decoded(), 0);
            assert!(!h.is_complete());
            let mut steps = 0;
            while !h.is_complete() {
                let n = b.decode_step(&mut h, 4).unwrap();
                assert!(n > 0 && n <= 4);
                steps += 1;
            }
            assert_eq!(steps, 4, "16 tokens in chunks of 4");
            assert_eq!(b.decode_step(&mut h, 4).unwrap(), 0, "budget exhausted");
            let rep = h.report();
            assert_eq!(rep.island, one_shot.island);
            assert_eq!(rep.cost, one_shot.cost, "island {id}: stepped cost must match one-shot");
            assert_eq!(rep.queued_ms, one_shot.queued_ms);
            assert_eq!(rep.payload_kb, one_shot.payload_kb);
            // chunked f64 accumulation may differ from the one-shot by ulps
            assert!((rep.latency_ms - one_shot.latency_ms).abs() < 1e-6, "island {id}: {rep:?} vs {one_shot:?}");
        }
    }

    #[test]
    fn abandoned_decode_frees_the_slot_immediately() {
        // mobile has 1 slot: a 512-token decode abandoned after 2 steps
        // must leave the slot booked only through the completed work
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(512);
        let mut h = f.prefill(IslandId(1), &r).unwrap();
        f.decode_step(&mut h, 4).unwrap();
        f.decode_step(&mut h, 4).unwrap();
        assert_eq!(h.tokens_decoded(), 8);
        let partial_cost = h.cost();
        // cost so far covers prefill + 8 tokens, strictly below the full run
        let full = Fleet::new(preset_personal_group(), 7).execute(IslandId(1), &r).unwrap();
        assert!(partial_cost <= full.cost);
        // drop the handle: just past the cursor the slot is free again,
        // ~2000 ms (504 tokens x 4 ms) before a full decode would end
        let freed_at = h.cursor_ms();
        drop(h);
        assert_eq!(f.get(IslandId(1)).unwrap().capacity(freed_at + 1.0), 1.0);
        assert!(f.prefill(IslandId(1), &r).is_ok(), "slot is reusable");
    }

    #[test]
    fn decode_step_fails_island_down_when_crashed_mid_decode() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(32);
        let mut h = f.prefill(IslandId(0), &r).unwrap();
        assert!(f.decode_step(&mut h, 4).is_ok());
        f.crash(IslandId(0));
        assert_eq!(f.decode_step(&mut h, 4), Err(ExecError::IslandDown(IslandId(0))));
        f.revive(IslandId(0));
        assert!(f.decode_step(&mut h, 4).is_ok(), "decode resumes after revive");
        // an island that left the fleet surfaces as unknown
        f.leave(IslandId(0));
        assert_eq!(f.decode_step(&mut h, 4), Err(ExecError::UnknownIsland(IslandId(0))));
    }

    #[test]
    fn crashed_island_refuses_execution_and_reports_zero_capacity() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        assert!(f.crash(IslandId(0)));
        assert_eq!(f.execute(IslandId(0), &r), Err(ExecError::IslandDown(IslandId(0))));
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(f.now()), 0.0);
        let st = f.states();
        assert!(!st.iter().find(|s| s.island.id == IslandId(0)).unwrap().online);
        // revive: serves again
        assert!(f.revive(IslandId(0)));
        assert!(f.execute(IslandId(0), &r).is_ok());
        // unknown islands are a different error
        assert!(!f.crash(IslandId(999)));
        assert_eq!(f.execute(IslandId(999), &r), Err(ExecError::UnknownIsland(IslandId(999))));
    }

    #[test]
    fn crashed_unbounded_island_reports_zero_capacity() {
        let f = fleet();
        assert_eq!(f.get(IslandId(5)).unwrap().capacity(0.0), 1.0);
        f.crash(IslandId(5));
        assert_eq!(f.get(IslandId(5)).unwrap().capacity(0.0), 0.0);
    }

    #[test]
    fn join_and_leave_change_membership() {
        let f = fleet();
        let n = f.len();
        let mut extra = preset_personal_group().remove(1);
        extra.id = IslandId(42);
        extra.name = "spare-workstation".to_string();
        assert!(f.join(extra.clone()));
        assert!(!f.join(extra.clone()), "duplicate id must be rejected");
        assert_eq!(f.len(), n + 1);
        let r = Request::new(1, "prompt");
        assert!(f.execute(IslandId(42), &r).is_ok());
        let left = f.leave(IslandId(42)).expect("leaves");
        assert_eq!(left.id, IslandId(42));
        assert_eq!(f.len(), n);
        assert_eq!(f.execute(IslandId(42), &r), Err(ExecError::UnknownIsland(IslandId(42))));
        assert!(f.leave(IslandId(42)).is_none());
    }

    #[test]
    fn concurrent_churn_and_execution_never_panics() {
        use std::sync::Arc as StdArc;
        let f = StdArc::new(fleet());
        let churn = {
            let f = StdArc::clone(&f);
            std::thread::spawn(move || {
                for i in 0..200u32 {
                    let id = IslandId(i % 5);
                    f.crash(id);
                    f.revive(id);
                    if i % 10 == 0 {
                        let mut extra = preset_personal_group().remove(1);
                        extra.id = IslandId(100 + (i % 3));
                        f.join(extra);
                        f.leave(IslandId(100 + (i % 3)));
                    }
                }
            })
        };
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let f = StdArc::clone(&f);
                std::thread::spawn(move || {
                    let r = Request::new(t, "prompt");
                    let mut served = 0usize;
                    for _ in 0..100 {
                        if f.execute(IslandId((t % 5) as u32), &r).is_ok() {
                            served += 1;
                        }
                        f.advance(50.0);
                    }
                    served
                })
            })
            .collect();
        churn.join().unwrap();
        let served: usize = workers.into_iter().map(|h| h.join().unwrap()).sum();
        // executed accounting matches successes exactly
        let executed: u64 = f.islands().iter().map(|i| i.executed()).sum();
        assert_eq!(executed as usize, served);
    }
}
