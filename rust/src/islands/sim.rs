//! Virtual-time island simulator.
//!
//! Models each island as a set of execution slots plus an optional external
//! load program; requests experience network RTT ([`crate::substrate::netsim`]),
//! queueing (earliest free slot) and compute time. Compute-time constants
//! are calibrated so end-to-end latencies land in the paper's §XI.B bands:
//!
//!   personal: 50–500 ms  · private edge: 100–1000 ms · cloud: 200–2000 ms
//!
//! (validated by eval E4 and integration tests). Unbounded (Tier-3) islands
//! never queue — HORIZON "scales to thousands of concurrent requests" — but
//! pay WAN latency and per-request cost.
//!
//! Concurrency: the fleet is shared behind `Arc<Orchestrator>`, so the
//! virtual clock is an atomic f64 and each island's runtime state (slots,
//! battery, external load) sits behind its own mutex — submitters routed to
//! different islands never contend, and WAVES admission reads capacity
//! without blocking writers for long.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::substrate::netsim::NetSim;
use crate::types::{Island, IslandId, Request, TrustTier};
use crate::util::AtomicF64;

/// Per-tier compute model: fixed startup + per-token milliseconds.
fn compute_model(tier: TrustTier) -> (f64, f64) {
    match tier {
        // (startup_ms, per_token_ms)
        TrustTier::Personal => (30.0, 4.0),
        TrustTier::PrivateEdge => (50.0, 2.0),
        TrustTier::Cloud => (90.0, 1.2),
    }
}

/// Payload a request moves over the network: prompt + history out, generated
/// tokens back (KB) — E11 accounting.
fn payload_kb(request: &Request) -> f64 {
    (request.prompt.len() + request.history.iter().map(|t| t.text.len()).sum::<usize>() + request.max_new_tokens)
        as f64
        / 1024.0
}

/// Why a simulated execution could not run. The distinction matters to the
/// orchestrator's failover path: both variants mean "this island cannot
/// serve the request right now" and trigger a re-route, but they are audited
/// with different reasons.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// No island with this id is in the fleet (it left, or never joined).
    UnknownIsland(IslandId),
    /// The island is present but crashed / powered off.
    IslandDown(IslandId),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::UnknownIsland(id) => write!(f, "island {id} not in fleet"),
            ExecError::IslandDown(id) => write!(f, "island {id} is offline"),
        }
    }
}

impl std::error::Error for ExecError {}

/// Outcome of one simulated execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecReport {
    pub island: IslandId,
    /// Virtual arrival time (ms).
    pub arrival_ms: f64,
    /// Total request latency: network + queue + compute (ms).
    pub latency_ms: f64,
    /// Time spent queued for a slot (ms).
    pub queued_ms: f64,
    /// Dollar cost charged.
    pub cost: f64,
    /// Bytes moved over the network (KB) — E11 accounting.
    pub payload_kb: f64,
}

/// Mutable runtime state of one island, guarded per island.
#[derive(Debug)]
struct IslandRt {
    /// Virtual time when each slot frees up (bounded islands).
    busy_until: Vec<f64>,
    /// External utilization in [0,1) (0 = idle), added on top of slot usage.
    external_load: f64,
    /// Remaining battery fraction for battery-powered islands.
    battery: Option<f64>,
    /// Total requests executed (telemetry).
    executed: u64,
}

/// One simulated island.
#[derive(Debug)]
pub struct SimIsland {
    pub spec: Island,
    rt: Mutex<IslandRt>,
    /// Power state: `false` = crashed / powered off. Flipped by
    /// [`Fleet::crash`] / [`Fleet::revive`] from churn drivers; an offline
    /// island reports zero capacity and refuses execution.
    online: AtomicBool,
}

impl SimIsland {
    pub fn new(spec: Island) -> SimIsland {
        let slots = spec.capacity_slots.unwrap_or(0);
        let battery = spec.battery;
        SimIsland {
            spec,
            rt: Mutex::new(IslandRt { busy_until: vec![0.0; slots], external_load: 0.0, battery, executed: 0 }),
            online: AtomicBool::new(true),
        }
    }

    pub fn is_online(&self) -> bool {
        self.online.load(Ordering::SeqCst)
    }

    pub fn set_online(&self, online: bool) {
        self.online.store(online, Ordering::SeqCst);
    }

    /// Available capacity R_j(t): fraction of free slots, reduced by the
    /// external load program. Unbounded islands always report 1.0; offline
    /// islands always report 0.0.
    pub fn capacity(&self, now_ms: f64) -> f64 {
        if !self.is_online() {
            return 0.0;
        }
        if self.spec.unbounded() {
            return 1.0;
        }
        let rt = self.rt.lock().unwrap();
        if rt.busy_until.is_empty() {
            return 0.0;
        }
        let free = rt.busy_until.iter().filter(|&&t| t <= now_ms).count() as f64;
        let slot_cap = free / rt.busy_until.len() as f64;
        (slot_cap * (1.0 - rt.external_load)).clamp(0.0, 1.0)
    }

    /// Set the external utilization knob (load programs / test scaffolding).
    pub fn set_external_load(&self, load: f64) {
        self.rt.lock().unwrap().external_load = load;
    }

    pub fn external_load(&self) -> f64 {
        self.rt.lock().unwrap().external_load
    }

    /// Current battery fraction, if battery-powered.
    pub fn battery(&self) -> Option<f64> {
        self.rt.lock().unwrap().battery
    }

    /// Total requests this island has executed.
    pub fn executed(&self) -> u64 {
        self.rt.lock().unwrap().executed
    }

    /// Execute a request arriving at `now_ms` with a pre-sampled network
    /// round trip; returns the report. The caller has already decided this
    /// island is the target (router) and sampled the link
    /// ([`Fleet::execute`] does both).
    pub fn execute(&self, request: &Request, now_ms: f64, rtt: f64, payload_kb: f64) -> Result<ExecReport, ExecError> {
        let tokens = request.token_estimate();
        let mut rt = self.rt.lock().unwrap();
        // checked under the rt lock so a crash() racing this call is seen
        // before any slot is booked
        if !self.is_online() {
            return Err(ExecError::IslandDown(self.spec.id));
        }
        let (startup, per_token) = compute_model(self.spec.tier);
        // external load slows compute proportionally
        let slow = 1.0 / (1.0 - rt.external_load.min(0.9));
        let compute = (startup + per_token * tokens as f64) * slow;

        let (queued, start) = if self.spec.unbounded() {
            (0.0, now_ms + rtt / 2.0)
        } else {
            // earliest-free-slot queueing
            let (slot_idx, &free_at) = rt
                .busy_until
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .expect("bounded island has slots");
            let start = (now_ms + rtt / 2.0).max(free_at);
            let queued = (free_at - (now_ms + rtt / 2.0)).max(0.0);
            rt.busy_until[slot_idx] = start + compute;
            (queued, start)
        };
        let finish = start + compute + rtt / 2.0;

        // battery drain: proportional to compute on battery islands
        if let Some(b) = rt.battery.as_mut() {
            *b = (*b - compute / 2_000_000.0).max(0.0);
        }
        rt.executed += 1;

        Ok(ExecReport {
            island: self.spec.id,
            arrival_ms: now_ms,
            latency_ms: finish - now_ms,
            queued_ms: queued,
            cost: self.spec.request_cost(tokens),
            payload_kb,
        })
    }
}

/// A mesh of simulated islands sharing a virtual clock.
///
/// Membership is dynamic: islands [`crash`](Fleet::crash) and
/// [`revive`](Fleet::revive) in place (power state), and
/// [`join`](Fleet::join) / [`leave`](Fleet::leave) the mesh entirely — all
/// through `&self`, so churn drivers (tests, the load generator's churn
/// thread) run concurrently with submitters. The island list sits behind an
/// `RwLock` of `Arc`s: the hot path takes a read lock just long enough to
/// clone the target's `Arc`, then executes against the island's own mutex.
#[derive(Debug)]
pub struct Fleet {
    islands: RwLock<Vec<Arc<SimIsland>>>,
    net: Mutex<NetSim>,
    now_ms: AtomicF64,
}

impl Fleet {
    pub fn new(specs: Vec<Island>, seed: u64) -> Fleet {
        Fleet {
            islands: RwLock::new(specs.into_iter().map(|s| Arc::new(SimIsland::new(s))).collect()),
            net: Mutex::new(NetSim::new(seed)),
            now_ms: AtomicF64::new(0.0),
        }
    }

    pub fn now(&self) -> f64 {
        self.now_ms.load()
    }

    /// Advance the virtual clock (atomic; callable from any thread).
    pub fn advance(&self, dt_ms: f64) {
        self.now_ms.fetch_add(dt_ms);
    }

    /// Snapshot of the current island list (membership may change the
    /// moment the read lock drops; the `Arc`s stay valid regardless).
    pub fn islands(&self) -> Vec<Arc<SimIsland>> {
        self.islands.read().unwrap().clone()
    }

    /// Current island specs (registration / discovery view).
    pub fn specs(&self) -> Vec<Island> {
        self.islands.read().unwrap().iter().map(|i| i.spec.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.islands.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.islands.read().unwrap().is_empty()
    }

    pub fn get(&self, id: IslandId) -> Option<Arc<SimIsland>> {
        self.islands.read().unwrap().iter().find(|i| i.spec.id == id).cloned()
    }

    /// Power an island off in place (it stays a fleet member: heartbeats
    /// stop, capacity reads 0, execution fails island-down). Returns false
    /// for unknown ids.
    pub fn crash(&self, id: IslandId) -> bool {
        match self.get(id) {
            Some(island) => {
                island.set_online(false);
                true
            }
            None => false,
        }
    }

    /// Power a crashed island back on. Returns false for unknown ids.
    pub fn revive(&self, id: IslandId) -> bool {
        match self.get(id) {
            Some(island) => {
                island.set_online(true);
                true
            }
            None => false,
        }
    }

    /// Add a new island to the mesh (dynamic discovery). Rejects duplicate
    /// ids; the new island starts online with fresh runtime state.
    pub fn join(&self, spec: Island) -> bool {
        let mut islands = self.islands.write().unwrap();
        if islands.iter().any(|i| i.spec.id == spec.id) {
            return false;
        }
        islands.push(Arc::new(SimIsland::new(spec)));
        true
    }

    /// Remove an island from the mesh entirely (clean leave). In-flight
    /// executions holding the island's `Arc` complete; new requests see
    /// `UnknownIsland`.
    pub fn leave(&self, id: IslandId) -> Option<Island> {
        let mut islands = self.islands.write().unwrap();
        let pos = islands.iter().position(|i| i.spec.id == id)?;
        Some(islands.remove(pos).spec.clone())
    }

    /// Drop every island whose spec fails the predicate (test scaffolding).
    pub fn retain(&self, pred: impl Fn(&Island) -> bool) {
        self.islands.write().unwrap().retain(|i| pred(&i.spec));
    }

    /// Router-facing dynamic state snapshot.
    pub fn states(&self) -> Vec<crate::agents::waves::IslandState> {
        let now = self.now();
        self.islands
            .read()
            .unwrap()
            .iter()
            .map(|i| crate::agents::waves::IslandState {
                island: i.spec.clone(),
                capacity: i.capacity(now),
                online: i.is_online(),
                // TIDE's degrade view is layered on by the orchestrator;
                // the raw fleet snapshot only knows power state
                degraded: false,
            })
            .collect()
    }

    /// TIDE's local view: mean capacity across the personal island group
    /// (the user's own devices — whichever of them is currently "local").
    pub fn local_capacity(&self) -> f64 {
        let now = self.now();
        let personal: Vec<f64> = self
            .islands
            .read()
            .unwrap()
            .iter()
            .filter(|i| i.spec.tier == TrustTier::Personal)
            .map(|i| i.capacity(now))
            .collect();
        if personal.is_empty() {
            0.0
        } else {
            personal.iter().sum::<f64>() / personal.len() as f64
        }
    }

    /// Execute on a chosen island at the current virtual time. Only the RTT
    /// sample holds the shared NetSim lock; slot booking and accounting run
    /// under the target island's own mutex, so executions on different
    /// islands overlap. Fails island-down when the target crashed between
    /// routing and execution (the orchestrator's failover path re-routes).
    pub fn execute(&self, id: IslandId, request: &Request) -> Result<ExecReport, ExecError> {
        let now = self.now();
        let island = self.get(id).ok_or(ExecError::UnknownIsland(id))?;
        if !island.is_online() {
            return Err(ExecError::IslandDown(id));
        }
        let payload_kb = payload_kb(request);
        let rtt = {
            let mut net = self.net.lock().unwrap();
            net.round_trip_retry(island.spec.link, payload_kb.max(0.5), 3).unwrap_or(5_000.0)
        };
        island.execute(request, now, rtt, payload_kb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn fleet() -> Fleet {
        Fleet::new(preset_personal_group(), 7)
    }

    #[test]
    fn latencies_fall_in_paper_bands() {
        // §XI.B: personal 50-500, edge 100-1000, cloud 200-2000 (ms)
        let f = fleet();
        let r = Request::new(1, &"x".repeat(200)).with_max_new_tokens(16);
        let mut check = |id: u32, lo: f64, hi: f64, name: &str| {
            let mut worst = (f64::INFINITY, 0.0f64);
            for _ in 0..50 {
                let rep = f.execute(IslandId(id), &r).unwrap();
                worst = (worst.0.min(rep.latency_ms), worst.1.max(rep.latency_ms));
                f.advance(10_000.0); // let slots clear
            }
            assert!(worst.0 >= lo * 0.5 && worst.1 <= hi * 1.5, "{name}: {worst:?} not near [{lo},{hi}]");
        };
        check(0, 50.0, 500.0, "laptop");
        check(4, 100.0, 1000.0, "edge");
        check(5, 200.0, 2000.0, "cloud");
    }

    #[test]
    fn bounded_islands_queue() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(32);
        // mobile has 1 slot: second request must queue
        let first = f.execute(IslandId(1), &r).unwrap();
        let second = f.execute(IslandId(1), &r).unwrap();
        assert_eq!(first.queued_ms, 0.0);
        assert!(second.queued_ms > 0.0, "{second:?}");
        assert!(second.latency_ms > first.latency_ms);
    }

    #[test]
    fn unbounded_cloud_never_queues() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        for _ in 0..100 {
            let rep = f.execute(IslandId(5), &r).unwrap();
            assert_eq!(rep.queued_ms, 0.0);
        }
    }

    #[test]
    fn capacity_reflects_slot_usage_and_recovers() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(64);
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(0.0), 1.0);
        for _ in 0..4 {
            f.execute(IslandId(0), &r).unwrap();
        }
        // laptop saturated; group mean reflects 3 idle devices
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(f.now()), 0.0);
        assert!(f.local_capacity() < 0.8);
        f.advance(60_000.0);
        assert_eq!(f.local_capacity(), 1.0);
    }

    #[test]
    fn external_load_reduces_capacity_and_slows_compute() {
        let f = fleet();
        let r = Request::new(1, "prompt").with_max_new_tokens(16);
        let fast = f.execute(IslandId(0), &r).unwrap();
        f.advance(60_000.0);
        f.get(IslandId(0)).unwrap().set_external_load(0.8);
        assert!(f.get(IslandId(0)).unwrap().capacity(f.now()) <= 0.2);
        let slow = f.execute(IslandId(0), &r).unwrap();
        assert!(slow.latency_ms > 2.0 * fast.latency_ms, "fast={fast:?} slow={slow:?}");
    }

    #[test]
    fn cloud_charges_money_local_is_free() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        assert_eq!(f.execute(IslandId(0), &r).unwrap().cost, 0.0);
        assert!(f.execute(IslandId(5), &r).unwrap().cost > 0.0);
    }

    #[test]
    fn battery_drains_with_use() {
        let f = fleet();
        let before = f.get(IslandId(1)).unwrap().battery().unwrap();
        let r = Request::new(1, "prompt").with_max_new_tokens(64);
        for _ in 0..20 {
            f.execute(IslandId(1), &r).unwrap();
            f.advance(10_000.0);
        }
        let after = f.get(IslandId(1)).unwrap().battery().unwrap();
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn states_snapshot_matches_islands() {
        let f = fleet();
        let st = f.states();
        assert_eq!(st.len(), 7);
        assert!(st.iter().all(|s| (0.0..=1.0).contains(&s.capacity)));
    }

    #[test]
    fn concurrent_executes_account_every_request() {
        use std::sync::Arc;
        let f = Arc::new(fleet());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    let r = Request::new(t, "prompt");
                    for _ in 0..50 {
                        // mix a bounded and an unbounded island
                        f.execute(IslandId((t % 2 * 5) as u32), &r).unwrap();
                        f.advance(100.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: u64 = f.islands().iter().map(|i| i.executed()).sum();
        assert_eq!(total, 400);
        assert!((f.now() - 40_000.0).abs() < 1e-6);
    }

    #[test]
    fn crashed_island_refuses_execution_and_reports_zero_capacity() {
        let f = fleet();
        let r = Request::new(1, "prompt");
        assert!(f.crash(IslandId(0)));
        assert_eq!(f.execute(IslandId(0), &r), Err(ExecError::IslandDown(IslandId(0))));
        assert_eq!(f.get(IslandId(0)).unwrap().capacity(f.now()), 0.0);
        let st = f.states();
        assert!(!st.iter().find(|s| s.island.id == IslandId(0)).unwrap().online);
        // revive: serves again
        assert!(f.revive(IslandId(0)));
        assert!(f.execute(IslandId(0), &r).is_ok());
        // unknown islands are a different error
        assert!(!f.crash(IslandId(999)));
        assert_eq!(f.execute(IslandId(999), &r), Err(ExecError::UnknownIsland(IslandId(999))));
    }

    #[test]
    fn crashed_unbounded_island_reports_zero_capacity() {
        let f = fleet();
        assert_eq!(f.get(IslandId(5)).unwrap().capacity(0.0), 1.0);
        f.crash(IslandId(5));
        assert_eq!(f.get(IslandId(5)).unwrap().capacity(0.0), 0.0);
    }

    #[test]
    fn join_and_leave_change_membership() {
        let f = fleet();
        let n = f.len();
        let mut extra = preset_personal_group().remove(1);
        extra.id = IslandId(42);
        extra.name = "spare-workstation".to_string();
        assert!(f.join(extra.clone()));
        assert!(!f.join(extra.clone()), "duplicate id must be rejected");
        assert_eq!(f.len(), n + 1);
        let r = Request::new(1, "prompt");
        assert!(f.execute(IslandId(42), &r).is_ok());
        let left = f.leave(IslandId(42)).expect("leaves");
        assert_eq!(left.id, IslandId(42));
        assert_eq!(f.len(), n);
        assert_eq!(f.execute(IslandId(42), &r), Err(ExecError::UnknownIsland(IslandId(42))));
        assert!(f.leave(IslandId(42)).is_none());
    }

    #[test]
    fn concurrent_churn_and_execution_never_panics() {
        use std::sync::Arc as StdArc;
        let f = StdArc::new(fleet());
        let churn = {
            let f = StdArc::clone(&f);
            std::thread::spawn(move || {
                for i in 0..200u32 {
                    let id = IslandId(i % 5);
                    f.crash(id);
                    f.revive(id);
                    if i % 10 == 0 {
                        let mut extra = preset_personal_group().remove(1);
                        extra.id = IslandId(100 + (i % 3));
                        f.join(extra);
                        f.leave(IslandId(100 + (i % 3)));
                    }
                }
            })
        };
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let f = StdArc::clone(&f);
                std::thread::spawn(move || {
                    let r = Request::new(t, "prompt");
                    let mut served = 0usize;
                    for _ in 0..100 {
                        if f.execute(IslandId((t % 5) as u32), &r).is_ok() {
                            served += 1;
                        }
                        f.advance(50.0);
                    }
                    served
                })
            })
            .collect();
        churn.join().unwrap();
        let served: usize = workers.into_iter().map(|h| h.join().unwrap()).sum();
        // executed accounting matches successes exactly
        let executed: u64 = f.islands().iter().map(|i| i.executed()).sum();
        assert_eq!(executed as usize, served);
    }
}
