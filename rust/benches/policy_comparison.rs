//! E1/E2/E3/E4 — end-to-end policy comparison: all six policies over the
//! §XI workload mix on the personal-group fleet, reporting the paper's
//! comparison dimensions (violations / cost / latency / local share) plus
//! harness wall-time per 1k requests.

use islandrun::baselines::all_policies;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::{run_policy, RunOpts};
use islandrun::substrate::trace::paper_mix;
use islandrun::util::bench::fmt_us;
use islandrun::util::Table;

fn main() {
    let trace = paper_mix(5000, 7);
    let mut t = Table::new(
        "policy_comparison — 5k requests, §XI mix (40/35/25)",
        &["policy", "violations", "$ / 1k", "p50 ms", "p99 ms", "local share", "sim wall / 1k req"],
    );
    for mut policy in all_policies(&Config::default()) {
        let t0 = std::time::Instant::now();
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 7, RunOpts::default());
        let wall_us = t0.elapsed().as_secs_f64() * 1e6 / (trace.len() as f64 / 1000.0);
        t.row(&[
            st.policy.to_string(),
            st.privacy_violations.to_string(),
            format!("${:.2}", st.cost_per_1k()),
            format!("{:.1}", st.p(0.5)),
            format!("{:.1}", st.p(0.99)),
            format!("{:.1}%", st.local_share * 100.0),
            fmt_us(wall_us),
        ]);
    }
    t.print();

    // pressure sweep: the paper's "who wins under load" shape
    let mut t2 = Table::new(
        "policy_comparison — violations under increasing load (islandrun vs static-policy)",
        &["interarrival ms", "islandrun viol.", "static viol.", "latency-greedy viol."],
    );
    for ia in [50.0, 10.0, 3.0] {
        let opts = RunOpts { interarrival_ms: ia, ..RunOpts::default() };
        let mut row = vec![format!("{ia:.0}")];
        for name in ["islandrun", "static-policy", "latency-greedy"] {
            let mut policy = all_policies(&Config::default()).into_iter().find(|p| p.name() == name).unwrap();
            let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 8, opts);
            row.push(st.privacy_violations.to_string());
        }
        t2.row(&row);
    }
    t2.print();
}
