//! E6 — agent ablation bench: quantifies what each agent buys, plus router
//! configuration ablations DESIGN.md calls out (scalarized vs
//! constraint-based mode; buffer profiles; hysteresis dead zone).

use islandrun::agents::tide::hysteresis::Hysteresis;
use islandrun::baselines::IslandRunPolicy;
use islandrun::config::{preset_personal_group, BufferProfile, Config, RouterMode};
use islandrun::eval::{run_policy, RunOpts};
use islandrun::substrate::trace::paper_mix;
use islandrun::util::Table;

fn main() {
    let trace = paper_mix(4000, 66);

    // --- agent ablation (mirrors eval e6, bench-grade sizes) -------------
    let mut t = Table::new(
        "ablation — disable one agent at a time (4k requests)",
        &["variant", "violations", "deadline misses", "p50 ms", "p99 ms"],
    );
    let cases: Vec<(&str, RunOpts)> = vec![
        ("full system", RunOpts::default()),
        ("no MIST (s_r=0)", RunOpts { force_s_r: Some(0.0), ..RunOpts::default() }),
        ("no TIDE (R=1)", RunOpts { force_capacity: Some(1.0), interarrival_ms: 4.0, ..RunOpts::default() }),
        ("no LIGHTHOUSE (+25ms)", RunOpts { discovery_penalty_ms: 25.0, ..RunOpts::default() }),
    ];
    for (name, opts) in cases {
        let mut p = IslandRunPolicy::new(Config::default());
        let st = run_policy(&mut p, &trace, preset_personal_group(), 66, opts);
        t.row(&[
            name.to_string(),
            st.privacy_violations.to_string(),
            st.deadline_misses.to_string(),
            format!("{:.1}", st.p(0.5)),
            format!("{:.1}", st.p(0.99)),
        ]);
    }
    t.print();

    // --- router mode ablation (§VI.C) -------------------------------------
    let mut t2 = Table::new(
        "ablation — scalarized (Eq. 1) vs constraint-based routing",
        &["mode", "violations", "$ / 1k", "p50 ms", "local share"],
    );
    for (name, mode) in [("scalarized", RouterMode::Scalarized), ("constraint-based", RouterMode::ConstraintBased)] {
        let mut cfg = Config::default();
        cfg.mode = mode;
        let mut p = IslandRunPolicy::new(cfg);
        let st = run_policy(&mut p, &trace, preset_personal_group(), 67, RunOpts::default());
        t2.row(&[
            name.to_string(),
            st.privacy_violations.to_string(),
            format!("${:.2}", st.cost_per_1k()),
            format!("{:.1}", st.p(0.5)),
            format!("{:.1}%", st.local_share * 100.0),
        ]);
    }
    t2.print();

    // --- buffer profile ablation (§IX.A) ----------------------------------
    let mut t3 = Table::new(
        "ablation — §IX.A buffer profiles under load (interarrival 6ms)",
        &["buffer", "violations", "$ / 1k", "p99 ms", "local share"],
    );
    for (name, b) in [
        ("conservative (30%)", BufferProfile::Conservative),
        ("moderate (20%)", BufferProfile::Moderate),
        ("aggressive (10%)", BufferProfile::Aggressive),
    ] {
        let mut cfg = Config::default();
        cfg.buffer = b;
        let mut p = IslandRunPolicy::new(cfg);
        let opts = RunOpts { interarrival_ms: 6.0, ..RunOpts::default() };
        let st = run_policy(&mut p, &trace, preset_personal_group(), 68, opts);
        t3.row(&[
            name.to_string(),
            st.privacy_violations.to_string(),
            format!("${:.2}", st.cost_per_1k()),
            format!("{:.1}", st.p(0.99)),
            format!("{:.1}%", st.local_share * 100.0),
        ]);
    }
    t3.print();

    // --- hysteresis dead zone (E10 shape) ----------------------------------
    let mut t4 = Table::new("ablation — hysteresis dead zone (1k oscillating samples)", &["variant", "flaps"]);
    let mut with = Hysteresis::new(0.70, 0.80);
    let mut without = Hysteresis::without_dead_zone(0.75);
    for i in 0..1000 {
        let r = 0.75 + if i % 2 == 0 { 0.04 } else { -0.04 };
        with.observe(r);
        without.observe(r);
    }
    t4.row(&["dead zone 70/80".to_string(), with.transitions().to_string()]);
    t4.row(&["single threshold 75".to_string(), without.transitions().to_string()]);
    t4.print();
}
