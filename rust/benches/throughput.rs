//! Concurrent-submit throughput: requests/sec through `Arc<Orchestrator>`
//! at 1, 4 and 16 closed-loop worker threads on the Sim backend.
//!
//! This is the tentpole measurement for the multi-threaded serving core:
//! the MIST stage-1 sweep, routing and per-island execution all run from
//! many threads at once; the only serialized pieces are short mutexes
//! around the audit log, the rate limiter, the hysteresis state machine and
//! each island's slot table. On a multi-core host 16 workers must clear at
//! least 2x the single-worker rate (asserted below when >= 4 cores are
//! available).
//!
//! Also benchmarks the telemetry hot path itself: per-request counter
//! bumps through a pre-resolved typed handle vs the legacy string-keyed
//! `count(name, n)` lookup, and gates that the handle path is no slower
//! (it should be much faster — one atomic add vs a read-locked map probe).
//!
//! And the tracing tax: the same open-loop run with the trace sink
//! disabled vs enabled at the production sampling posture (1% head rate),
//! gated to cost at most 5% of requests/sec. Sampling decisions happen at
//! the terminal, so span bookkeeping is on the hot path even for traces
//! that end up dropped — this is the number that keeps tracing
//! always-on-able.
//!
//! CI hooks: `ISLANDRUN_BENCH_REQUESTS` overrides the total request count
//! (the bench-smoke job uses a short run), `ISLANDRUN_BENCH_GATE=off`
//! disables the speedup assertions and the telemetry no-regression gate
//! (smoke runs measure, they do not gate), and
//! `ISLANDRUN_BENCH_JSON=<path>` writes the measured rows as a JSON
//! artifact (uploaded as `BENCH_throughput.json`).

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::{run_closed_loop, run_open_loop};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator};
use islandrun::telemetry::Metrics;
use islandrun::util::bench::write_json_artifact;
use islandrun::util::Table;

fn total_requests() -> usize {
    std::env::var("ISLANDRUN_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000)
}

use islandrun::util::bench::gate_enabled;

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the load generator measures pipeline throughput, not admission policy:
    // disable the knobs that would turn work away
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = total_requests();
    println!("throughput — closed-loop concurrent submit (Sim backend), {cores} cores, {total} requests\n");

    let mut t = Table::new(
        "throughput — requests/sec vs worker threads",
        &["threads", "req/s", "p99 ms", "served", "fail-closed", "errors", "wall s", "speedup vs 1"],
    );
    let mut rates = Vec::new();
    let mut json_rows = Vec::new();
    for &threads in &[1usize, 4, 16] {
        let orch = orchestrator(42 + threads as u64);
        let report = run_closed_loop(&orch, threads, total / threads, 7);
        assert_eq!(report.outcomes.len() + report.errors, report.attempted, "lost submissions");
        assert_eq!(orch.audit.len(), report.outcomes.len(), "audit trail must cover every admitted request");
        let rate = report.requests_per_sec();
        // served-latency p99 straight from the orchestrator's histogram
        let p99 = orch.metrics.histogram("latency_ms").map(|h| h.p99()).unwrap_or(0.0);
        rates.push((threads, rate));
        let speedup = rate / rates[0].1;
        t.row(&[
            threads.to_string(),
            format!("{rate:.0}"),
            format!("{p99:.1}"),
            report.served().to_string(),
            report.rejected().to_string(),
            report.errors.to_string(),
            format!("{:.2}", report.wall_s),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(vec![
            ("threads".to_string(), threads as f64),
            ("req_per_s".to_string(), rate),
            ("p99_ms".to_string(), p99),
            ("served".to_string(), report.served() as f64),
            ("rejected".to_string(), report.rejected() as f64),
            ("speedup".to_string(), speedup),
        ]);
    }
    t.print();

    let r1 = rates[0].1;
    let r16 = rates[2].1;
    let speedup = r16 / r1;
    if !gate_enabled() {
        println!("GATE OFF: measured {speedup:.2}x at 16 workers on {cores} cores (smoke run, not asserted)");
    } else if cores >= 4 {
        assert!(speedup >= 2.0, "expected >= 2x at 16 workers vs 1, measured {speedup:.2}x on {cores} cores");
        println!("PASS: 16-thread speedup {speedup:.2}x >= 2x (acceptance criterion)");
    } else if cores >= 2 {
        assert!(speedup >= 1.2, "expected some scaling on {cores} cores, measured {speedup:.2}x");
        println!("PASS (reduced): {speedup:.2}x speedup on only {cores} cores; the 2x gate needs >= 4");
    } else {
        println!("SKIP scaling assertion: single-core host ({speedup:.2}x measured)");
    }

    telemetry_hot_path_bench();
    json_rows.extend(tracing_overhead_bench(total));
    write_json_artifact("throughput", &json_rows);
}

/// Tracing-overhead gate: identical open-loop runs (the traced `enqueue`
/// path) with `trace_enabled` off vs on at head rate 0.01 — the
/// production posture where the tail policy keeps failures and slow
/// outliers but head-samples served traffic down to 1%. Span bookkeeping
/// is a few unsynchronized field writes per lifecycle stage plus one
/// mutex push per *kept* trace, so enabling it may cost at most 5% of
/// throughput. Best-of-3 per side to shave scheduler noise;
/// `ISLANDRUN_BENCH_GATE=off` measures without asserting.
fn tracing_overhead_bench(total: usize) -> Vec<Vec<(String, f64)>> {
    const PRODUCERS: usize = 4;
    const REPS: u64 = 3;
    let run = |traced: bool, seed: u64| -> f64 {
        let mut best = 0.0f64;
        for rep in 0..REPS {
            let mut cfg = Config::default();
            cfg.rate_limit_rps = 1e9;
            cfg.budget_ceiling = 1e9;
            cfg.trace_enabled = traced;
            cfg.trace_head_rate = 0.01;
            let fleet = Fleet::new(preset_personal_group(), seed + rep);
            let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed + rep));
            let report = run_open_loop(&orch, PRODUCERS, total / PRODUCERS, 7);
            assert_eq!(report.outcomes.len(), report.attempted, "open loop resolves every ticket");
            if traced {
                assert_eq!(orch.traces.started(), report.attempted as u64, "every enqueue opens a root span");
            } else {
                assert_eq!(orch.traces.started(), 0, "disabled sink must stay inert");
            }
            best = best.max(report.requests_per_sec());
        }
        best
    };
    let base = run(false, 1042);
    let traced = run(true, 2042);
    let ratio = traced / base;
    println!(
        "\ntracing overhead: off {base:.0} req/s vs on @ 1% head {traced:.0} req/s ({:+.1}% throughput)",
        (ratio - 1.0) * 100.0
    );
    if gate_enabled() {
        assert!(
            ratio >= 0.95,
            "tracing at 1% head sampling may cost at most 5% of throughput: {base:.0} -> {traced:.0} req/s"
        );
        println!("PASS: tracing tax within the 5% budget (acceptance criterion)");
    } else {
        println!("GATE OFF: tracing overhead measured, not enforced");
    }
    vec![
        vec![("tracing_enabled".to_string(), 0.0), ("req_per_s".to_string(), base)],
        vec![("tracing_enabled".to_string(), 1.0), ("req_per_s".to_string(), traced)],
    ]
}

/// Microbench: N counter bumps through a pre-resolved handle vs the legacy
/// string-keyed `count(name, 1)` path (name-table read lock + BTreeMap
/// probe per bump). The tentpole claim is that handles make per-request
/// telemetry effectively free, so the gate only requires "no slower" with
/// generous slack for timer noise on shared runners.
fn telemetry_hot_path_bench() {
    const BUMPS: u64 = 200_000;
    const REPS: usize = 5;
    let m = Metrics::new();
    let handle = m.register_counter("bench_handle_bumps", "microbench: cached-handle counter bumps");
    // warm both paths so first-touch registration stays out of the timings
    handle.inc();
    m.count("bench_string_bumps", 1);

    let time = |f: &mut dyn FnMut()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..REPS {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best * 1e9 / BUMPS as f64
    };
    let handle_ns = time(&mut || {
        for _ in 0..BUMPS {
            handle.inc();
        }
    });
    let string_ns = time(&mut || {
        for _ in 0..BUMPS {
            m.count("bench_string_bumps", 1);
        }
    });
    assert_eq!(m.counter_value("bench_handle_bumps"), 1 + REPS as u64 * BUMPS);
    assert_eq!(m.counter_value("bench_string_bumps"), 1 + REPS as u64 * BUMPS);

    println!(
        "
telemetry hot path: handle {handle_ns:.1} ns/bump vs string-keyed {string_ns:.1} ns/bump ({:.2}x)",
        string_ns / handle_ns
    );
    if gate_enabled() {
        assert!(
            handle_ns <= string_ns * 1.25,
            "typed handles must not be slower than the string-keyed path: {handle_ns:.1} > {string_ns:.1} ns/bump"
        );
        println!("PASS: handle-based counters are no slower than the string-keyed path");
    } else {
        println!("GATE OFF: telemetry comparison not enforced");
    }
}
