//! Concurrent-submit throughput: requests/sec through `Arc<Orchestrator>`
//! at 1, 4 and 16 closed-loop worker threads on the Sim backend.
//!
//! This is the tentpole measurement for the multi-threaded serving core:
//! the MIST stage-1 sweep, routing and per-island execution all run from
//! many threads at once; the only serialized pieces are short mutexes
//! around the audit log, the rate limiter, the hysteresis state machine and
//! each island's slot table. On a multi-core host 16 workers must clear at
//! least 2x the single-worker rate (asserted below when >= 4 cores are
//! available).

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::run_closed_loop;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator};
use islandrun::util::Table;

const TOTAL_REQUESTS: usize = 4000;

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the load generator measures pipeline throughput, not admission policy:
    // disable the knobs that would turn work away
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("throughput — closed-loop concurrent submit (Sim backend), {cores} cores\n");

    let mut t = Table::new(
        "throughput — requests/sec vs worker threads (4000 requests total)",
        &["threads", "req/s", "served", "fail-closed", "errors", "wall s", "speedup vs 1"],
    );
    let mut rates = Vec::new();
    for &threads in &[1usize, 4, 16] {
        let orch = orchestrator(42 + threads as u64);
        let report = run_closed_loop(&orch, threads, TOTAL_REQUESTS / threads, 7);
        assert_eq!(report.outcomes.len() + report.errors, report.attempted, "lost submissions");
        assert_eq!(orch.audit.len(), report.outcomes.len(), "audit trail must cover every admitted request");
        let rate = report.requests_per_sec();
        rates.push((threads, rate));
        let speedup = rate / rates[0].1;
        t.row(&[
            threads.to_string(),
            format!("{rate:.0}"),
            report.served().to_string(),
            report.rejected().to_string(),
            report.errors.to_string(),
            format!("{:.2}", report.wall_s),
            format!("{speedup:.2}x"),
        ]);
    }
    t.print();

    let r1 = rates[0].1;
    let r16 = rates[2].1;
    let speedup = r16 / r1;
    if cores >= 4 {
        assert!(speedup >= 2.0, "expected >= 2x at 16 workers vs 1, measured {speedup:.2}x on {cores} cores");
        println!("PASS: 16-thread speedup {speedup:.2}x >= 2x (acceptance criterion)");
    } else if cores >= 2 {
        assert!(speedup >= 1.2, "expected some scaling on {cores} cores, measured {speedup:.2}x");
        println!("PASS (reduced): {speedup:.2}x speedup on only {cores} cores; the 2x gate needs >= 4");
    } else {
        println!("SKIP scaling assertion: single-core host ({speedup:.2}x measured)");
    }
}
