//! E7 / §VI.B — routing decision latency vs island count n and pattern
//! count m. The paper claims `O(|q|·m + n)` with <10 ms routing at n < 10,
//! m ≈ 50. This bench regenerates that claim's table.

use islandrun::agents::mist::Mist;
use islandrun::agents::tide::hysteresis::Preference;
use islandrun::agents::waves::{IslandState, Waves};
use islandrun::config::{preset_personal_group, Config};
use islandrun::types::{IslandId, Request};
use islandrun::util::bench::{bench, report};

fn states_of(n: usize) -> Vec<IslandState> {
    let base = preset_personal_group();
    (0..n)
        .map(|i| {
            let mut s = base[i % base.len()].clone();
            s.id = IslandId(i as u32);
            IslandState { island: s, capacity: 0.8, online: true, degraded: false }
        })
        .collect()
}

fn main() {
    let mist = Mist::heuristic();
    let waves = Waves::new(Config::default());
    let request =
        Request::new(1, "patient john doe ssn 123-45-6789 diagnosed with diabetes, adjust metformin 500 mg daily");

    // --- full pipeline (MIST stage-1 m~50 regexes + route) vs n ----------
    let mut results = Vec::new();
    for n in [2usize, 4, 8, 16, 32, 64, 128] {
        let states = states_of(n);
        results.push(bench(&format!("mist+route n={n}"), 50, 2000, || {
            let s_r = mist.analyze(&request).score;
            let d = waves.route(&request, s_r, &states, 0.8, Preference::Local, f64::INFINITY);
            std::hint::black_box(d);
        }));
    }
    report("routing_latency — full decision (O(|q|m + n)); paper target <10ms @ n<10", &results);

    // --- route-only (isolates the O(n) term) ------------------------------
    let mut route_only = Vec::new();
    for n in [8usize, 64, 512] {
        let states = states_of(n);
        route_only.push(bench(&format!("route-only n={n}"), 50, 2000, || {
            let d = waves.route(&request, 0.9, &states, 0.8, Preference::Local, f64::INFINITY);
            std::hint::black_box(d);
        }));
    }
    report("routing_latency — router only (scaling in n)", &route_only);

    // --- MIST-only vs prompt length (the O(|q|·m) term) -------------------
    let mut mist_only = Vec::new();
    for len in [64usize, 256, 1024, 4096] {
        let prompt = "patient data ".repeat(len / 13 + 1);
        let r = Request::new(1, &prompt[..len]);
        mist_only.push(bench(&format!("mist |q|={len}"), 20, 500, || {
            std::hint::black_box(mist.analyze(&r).score);
        }));
    }
    report("routing_latency — MIST stage-1 vs prompt length", &mist_only);

    // the paper's headline claim, asserted
    let claim = &results[2]; // n=8
    assert!(claim.p99_us < 10_000.0, "paper claim violated: {:?}", claim);
    println!("PASS: n=8 p99 {} < 10ms (paper §VI.B)", islandrun::util::bench::fmt_us(claim.p99_us));
}
