//! Failover bench: routed throughput and p99 added latency when a fraction
//! of the fleet is down.
//!
//! Three phases on identical workloads (8 closed-loop workers): 0%, 10% and
//! 30% of islands crashed *silently* before the run — the liveness view has
//! to discover each death through failed executions or heartbeat timeouts,
//! so the measured overhead includes the failover re-routes, not just the
//! smaller fleet. Reported per phase: req/s, p99 latency of served
//! requests, served/rejected split, failover count and failover rate.
//!
//! Latency percentiles are read from the orchestrator's own labeled
//! histograms: the fleet-wide p99 from the `latency_ms` histogram
//! and a per-island breakdown (p50/p99/served) from the
//! `island_latency_ms{island,tier,privacy}` children — the bench reports
//! exactly what `render_prometheus()` exposes.
//!
//! CI hooks: `ISLANDRUN_BENCH_REQUESTS` overrides the total request count,
//! `ISLANDRUN_BENCH_JSON=<path>` writes the rows (fleet-wide and
//! per-island) as a JSON artifact (uploaded as `BENCH_failover.json`).

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::run_closed_loop;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator};
use islandrun::util::bench::write_json_artifact;
use islandrun::util::Table;

const THREADS: usize = 8;

fn total_requests() -> usize {
    std::env::var("ISLANDRUN_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000)
}

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

fn main() {
    let total = total_requests();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("failover — throughput/p99 vs island-down rate ({THREADS} workers, {total} requests, {cores} cores)\n");

    let mut t = Table::new(
        "failover — routed throughput and p99 latency vs fraction of islands down",
        &["down", "req/s", "p99 ms", "served", "rejected", "failovers", "failover rate", "Δp99 vs 0%"],
    );
    let mut json_rows = Vec::new();
    let mut per_island_rows = Vec::new();
    let mut baseline_p99 = 0.0f64;
    let mut baseline_rate = 0.0f64;
    for (phase, down_rate) in [0.0f64, 0.1, 0.3].into_iter().enumerate() {
        let orch = orchestrator(1000 + phase as u64);
        // silently crash the first ceil(down_rate * n) islands: the
        // liveness view must *discover* each death mid-run
        let ids = orch.island_ids();
        let down_count = (down_rate * ids.len() as f64).ceil() as usize;
        for id in ids.iter().take(down_count) {
            orch.silent_crash_island(*id);
        }
        let report = run_closed_loop(&orch, THREADS, total / THREADS, 7);
        assert_eq!(report.outcomes.len() + report.errors, report.attempted, "lost submissions");
        assert_eq!(orch.audit.len(), report.outcomes.len(), "audit trail must cover every admitted request");

        let rate = report.requests_per_sec();
        // fleet-wide served-latency distribution from the orchestrator's
        // own histogram — no bench-side sample collection
        let latency = orch.metrics.histogram("latency_ms").expect("latency_ms registered");
        assert_eq!(latency.count(), report.served() as u64, "histogram samples == served requests");
        let p99 = latency.p99();
        let failovers = orch.metrics.counter_value("failovers");
        let failover_rate = failovers as f64 / report.attempted as f64;
        if phase == 0 {
            baseline_p99 = p99;
            baseline_rate = rate;
        }
        t.row(&[
            format!("{:.0}%", down_rate * 100.0),
            format!("{rate:.0}"),
            format!("{p99:.1}"),
            report.served().to_string(),
            report.rejected().to_string(),
            failovers.to_string(),
            format!("{failover_rate:.3}"),
            format!("{:+.1}", p99 - baseline_p99),
        ]);
        json_rows.push(vec![
            ("down_rate".to_string(), down_rate),
            ("req_per_s".to_string(), rate),
            ("p99_ms".to_string(), p99),
            ("served".to_string(), report.served() as f64),
            ("rejected".to_string(), report.rejected() as f64),
            ("failovers".to_string(), failovers as f64),
            ("failover_rate".to_string(), failover_rate),
            ("added_p99_ms".to_string(), p99 - baseline_p99),
        ]);

        // per-island latency breakdown, straight from the labeled
        // histogram children (labels: island, tier, privacy)
        let mut children = orch.metrics.histogram_children("island_latency_ms");
        children.sort_by(|a, b| a.0.cmp(&b.0));
        let mut it = Table::new(
            &format!("failover — per-island served latency at {:.0}% down", down_rate * 100.0),
            &["island", "tier", "privacy", "served", "p50 ms", "p99 ms"],
        );
        for (labels, h) in &children {
            it.row(&[
                labels[0].clone(),
                labels[1].clone(),
                labels[2].clone(),
                h.count().to_string(),
                format!("{:.1}", h.p50()),
                format!("{:.1}", h.p99()),
            ]);
            let island_idx: f64 =
                labels[0].strip_prefix("island-").and_then(|n| n.parse().ok()).unwrap_or(-1.0);
            per_island_rows.push(vec![
                ("down_rate".to_string(), down_rate),
                ("island".to_string(), island_idx),
                ("served".to_string(), h.count() as f64),
                ("p50_ms".to_string(), h.p50()),
                ("p99_ms".to_string(), h.p99()),
            ]);
        }
        it.print();
    }
    t.print();
    json_rows.extend(per_island_rows);
    write_json_artifact("failover", &json_rows);

    println!(
        "\nbaseline: {baseline_rate:.0} req/s, p99 {baseline_p99:.1} ms — degraded phases measured above"
    );
}
