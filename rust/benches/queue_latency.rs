//! Queue-path latency: enqueue→resolve wall time through the admission
//! queue + worker pool (Sim backend) at 1, 4 and 16 producers.
//!
//! Each producer runs a closed loop over the non-blocking surface: enqueue
//! one request, wait its Ticket, record the elapsed wall time, repeat. That
//! measures the full lifecycle overhead a caller of `enqueue` observes —
//! admission, queue wait, routing, coalesced execution and ticket
//! resolution — under increasing producer concurrency against a fixed
//! 4-thread worker pool.
//!
//! CI hooks: `ISLANDRUN_BENCH_REQUESTS` overrides the total request count
//! (the bench-smoke job uses a short run) and `ISLANDRUN_BENCH_JSON=<path>`
//! writes the measured rows as a JSON artifact (uploaded as
//! `BENCH_queue.json`).

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::runtime::BatchPolicy;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::{priority_for, prompt_for};
use islandrun::util::bench::write_json_artifact;
use islandrun::util::{stats, Rng, Table};

fn total_requests() -> usize {
    std::env::var("ISLANDRUN_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000)
}

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the bench measures lifecycle latency, not admission policy
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = 4;
    let fleet = Fleet::new(preset_personal_group(), seed);
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed));
    // zero batch linger: measure queue + pipeline overhead, not the
    // deliberate latency-for-occupancy wait of the default policy
    orch.set_batch_policy(BatchPolicy { max_batch: 8, max_wait: std::time::Duration::ZERO });
    orch
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = total_requests();
    println!("queue_latency — enqueue→resolve via the admission queue (Sim), {cores} cores, {total} requests\n");

    let mut t = Table::new(
        "queue_latency — enqueue→resolve wall time vs producer count (4 workers)",
        &["producers", "req/s", "p50 ms", "p99 ms", "served", "rejected", "errors"],
    );
    let mut json_rows = Vec::new();
    for &producers in &[1usize, 4, 16] {
        let orch = orchestrator(900 + producers as u64);
        Arc::clone(&orch).start_queue();
        let per = (total / producers).max(1);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let orch = Arc::clone(&orch);
                std::thread::spawn(move || {
                    let session = orch.open_session(&format!("qbench-{p}"));
                    let mut rng = Rng::new(41 ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    let mut samples = Vec::with_capacity(per);
                    let mut served = 0usize;
                    let mut rejected = 0usize;
                    let mut errors = 0usize;
                    for i in 0..per {
                        let class = class_for(i);
                        let submit = SubmitRequest::new(prompt_for(class, &mut rng))
                            .priority(priority_for(class))
                            .deadline_ms(1e12);
                        let start = std::time::Instant::now();
                        let ticket = orch.enqueue(session, submit);
                        match ticket.wait() {
                            Ok(out) => {
                                samples.push(start.elapsed().as_secs_f64() * 1e3);
                                if out.decision.target().is_some() {
                                    served += 1;
                                } else {
                                    rejected += 1;
                                }
                            }
                            Err(_) => errors += 1,
                        }
                        orch.advance(5.0);
                    }
                    (samples, served, rejected, errors)
                })
            })
            .collect();
        let mut samples = Vec::with_capacity(producers * per);
        let (mut served, mut rejected, mut errors) = (0usize, 0usize, 0usize);
        for h in handles {
            let (s, sv, rj, er) = h.join().unwrap();
            samples.extend(s);
            served += sv;
            rejected += rj;
            errors += er;
        }
        let wall = t0.elapsed().as_secs_f64();
        let attempted = producers * per;
        assert_eq!(served + rejected + errors, attempted, "lost tickets");
        assert_eq!(errors, 0, "no ticket may resolve with an error");
        assert_eq!(orch.audit.len(), attempted, "audit trail must cover every enqueued request");
        assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);

        let rate = attempted as f64 / wall.max(1e-9);
        let p50 = stats::percentile(&samples, 0.5);
        let p99 = stats::percentile(&samples, 0.99);
        t.row(&[
            producers.to_string(),
            format!("{rate:.0}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            served.to_string(),
            rejected.to_string(),
            errors.to_string(),
        ]);
        json_rows.push(vec![
            ("producers".to_string(), producers as f64),
            ("req_per_s".to_string(), rate),
            ("p50_ms".to_string(), p50),
            ("p99_ms".to_string(), p99),
            ("served".to_string(), served as f64),
            ("rejected".to_string(), rejected as f64),
        ]);
    }
    t.print();
    write_json_artifact("queue", &json_rows);
}
