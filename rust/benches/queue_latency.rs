//! Queue-path latency: enqueue→resolve wall time through the admission
//! queue + worker pool (Sim backend) at 1, 4 and 16 producers, for BOTH
//! batching modes — run-to-completion coalescing and continuous
//! (decode-step) batching.
//!
//! Each producer runs a closed loop over the non-blocking surface: enqueue
//! one request, block on the ticket's TokenStream for the FIRST event
//! (time-to-first-token: in continuous mode tokens stream at decode-chunk
//! boundaries; in coalesce mode the first event is the terminal, so TTFT
//! equals completion), then wait the ticket and record end-to-end wall
//! time. That measures both the full lifecycle overhead and the streaming
//! head-start continuous batching buys under increasing producer
//! concurrency against a fixed 4-thread worker pool.
//!
//! Wall-time and TTFT samples are recorded through pre-registered labeled
//! histogram handles (`bench_wall_ms{mode,producers}` /
//! `bench_ttft_ms{mode,producers}`) on the orchestrator's own registry, and
//! every reported percentile is read back from the histogram snapshot — the
//! artifact exercises the same telemetry path production metrics use.
//!
//! CI hooks: `ISLANDRUN_BENCH_REQUESTS` overrides the total request count
//! (the bench-smoke job uses a short run), `ISLANDRUN_BENCH_JSON=<path>`
//! writes the measured rows as a JSON artifact (uploaded as
//! `BENCH_queue.json`), and `ISLANDRUN_BENCH_GATE=off` disables the final
//! continuous-vs-coalesce comparison gate (throughput and p99 TTFT at 16
//! producers) for smoke runs on noisy shared runners.

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::runtime::{BatchMode, BatchPolicy};
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::{priority_for, prompt_for};
use islandrun::util::bench::{gate_enabled, write_json_artifact};
use islandrun::util::{Rng, Table};

fn total_requests() -> usize {
    std::env::var("ISLANDRUN_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000)
}

fn orchestrator(seed: u64, mode: BatchMode) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the bench measures lifecycle latency, not admission policy
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = 4;
    let fleet = Fleet::new(preset_personal_group(), seed);
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed));
    // zero batch linger: measure queue + pipeline overhead, not the
    // deliberate latency-for-occupancy wait of the default policy
    let policy = BatchPolicy { max_batch: 8, max_wait: std::time::Duration::ZERO, mode, ..BatchPolicy::default() };
    orch.set_batch_policy(policy);
    orch
}

fn mode_name(mode: BatchMode) -> &'static str {
    match mode {
        BatchMode::Coalesce => "coalesce",
        BatchMode::Continuous => "continuous",
    }
}

struct Row {
    mode: BatchMode,
    producers: usize,
    rate: f64,
    ttft_p99: f64,
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = total_requests();
    println!("queue_latency — enqueue→first-token→resolve via the admission queue (Sim)");
    println!("{cores} cores, {total} requests\n");

    let mut t = Table::new(
        "queue_latency — wall time vs producer count and batch mode (4 workers)",
        &["mode", "producers", "req/s", "p50 ms", "p99 ms", "ttft p50", "ttft p99", "occupancy", "served", "rejected"],
    );
    let mut json_rows = Vec::new();
    let mut gate_rows: Vec<Row> = Vec::new();
    for &mode in &[BatchMode::Coalesce, BatchMode::Continuous] {
        for &producers in &[1usize, 4, 16] {
            let orch = orchestrator(900 + producers as u64, mode);
            Arc::clone(&orch).start_queue();
            // labeled histogram handles on the orchestrator's own registry:
            // the cells are resolved ONCE here and bumped lock-free in the
            // producer loops, exactly like the serving hot path
            let label_producers = producers.to_string();
            let wall_vec = orch.metrics.histogram_vec(
                "bench_wall_ms",
                "bench: enqueue->resolve wall time (ms)",
                &["mode", "producers"],
            );
            let ttft_vec = orch.metrics.histogram_vec(
                "bench_ttft_ms",
                "bench: enqueue->first-token wall time (ms)",
                &["mode", "producers"],
            );
            let wall_hist = wall_vec.with(&[mode_name(mode), &label_producers]);
            let ttft_hist = ttft_vec.with(&[mode_name(mode), &label_producers]);
            let per = (total / producers).max(1);
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = (0..producers)
                .map(|p| {
                    let orch = Arc::clone(&orch);
                    let wall_hist = wall_hist.clone();
                    let ttft_hist = ttft_hist.clone();
                    std::thread::spawn(move || {
                        let session = orch.open_session(&format!("qbench-{p}"));
                        let mut rng = Rng::new(41 ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                        let mut served = 0usize;
                        let mut rejected = 0usize;
                        let mut errors = 0usize;
                        for i in 0..per {
                            let class = class_for(i);
                            let submit = SubmitRequest::new(prompt_for(class, &mut rng))
                                .priority(priority_for(class))
                                .deadline_ms(1e12);
                            let start = std::time::Instant::now();
                            let ticket = orch.enqueue(session, submit);
                            // TTFT: block for the first stream event only.
                            // Continuous pushes it at the first decode chunk;
                            // coalesce resolves in one shot, so its first
                            // event IS the terminal.
                            let first = ticket.stream().next();
                            let ttft = start.elapsed().as_secs_f64() * 1e3;
                            debug_assert!(first.is_some(), "a stream always yields at least the terminal");
                            match ticket.wait() {
                                Ok(out) => {
                                    wall_hist.observe(start.elapsed().as_secs_f64() * 1e3);
                                    ttft_hist.observe(ttft);
                                    if out.decision.target().is_some() {
                                        served += 1;
                                    } else {
                                        rejected += 1;
                                    }
                                }
                                Err(_) => errors += 1,
                            }
                            orch.advance(5.0);
                        }
                        (served, rejected, errors)
                    })
                })
                .collect();
            let (mut served, mut rejected, mut errors) = (0usize, 0usize, 0usize);
            for h in handles {
                let (sv, rj, er) = h.join().unwrap();
                served += sv;
                rejected += rj;
                errors += er;
            }
            let wall = t0.elapsed().as_secs_f64();
            let attempted = producers * per;
            assert_eq!(served + rejected + errors, attempted, "lost tickets");
            assert_eq!(errors, 0, "no ticket may resolve with an error");
            assert_eq!(orch.audit.len(), attempted, "audit trail must cover every enqueued request");
            assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);

            let rate = attempted as f64 / wall.max(1e-9);
            // percentiles come from the labeled histogram snapshots — the
            // same data `render_prometheus()` would expose
            let wall_snap = wall_hist.snapshot();
            let ttft_snap = ttft_hist.snapshot();
            assert_eq!(wall_snap.count() + errors as u64, attempted as u64, "every resolved ticket is sampled");
            let p50 = wall_snap.p50();
            let p99 = wall_snap.p99();
            let ttft_p50 = ttft_snap.p50();
            let ttft_p99 = ttft_snap.p99();
            // mean in-flight requests per step-loop round (0 when the mode
            // never ran a step loop, i.e. coalesce)
            let occupancy = orch.metrics.histogram("batch_occupancy").map(|h| h.mean()).unwrap_or(0.0);
            t.row(&[
                mode_name(mode).to_string(),
                producers.to_string(),
                format!("{rate:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
                format!("{ttft_p50:.2}"),
                format!("{ttft_p99:.2}"),
                format!("{occupancy:.2}"),
                served.to_string(),
                rejected.to_string(),
            ]);
            json_rows.push(vec![
                ("mode".to_string(), if mode == BatchMode::Continuous { 1.0 } else { 0.0 }),
                ("producers".to_string(), producers as f64),
                ("req_per_s".to_string(), rate),
                ("p50_ms".to_string(), p50),
                ("p99_ms".to_string(), p99),
                ("ttft_p50_ms".to_string(), ttft_p50),
                ("ttft_p99_ms".to_string(), ttft_p99),
                ("steady_state_batch_occupancy".to_string(), occupancy),
                ("served".to_string(), served as f64),
                ("rejected".to_string(), rejected as f64),
            ]);
            gate_rows.push(Row { mode, producers, rate, ttft_p99 });
        }
    }
    t.print();
    write_json_artifact("queue", &json_rows);

    // The tentpole claim, gated: at 16 producers, continuous batching must
    // beat run-to-completion coalescing on BOTH throughput and p99 TTFT.
    // `ISLANDRUN_BENCH_GATE=off` skips the assertion (smoke runs on shared
    // runners), but the fields always land in the JSON artifact above.
    let find = |mode: BatchMode| {
        gate_rows
            .iter()
            .find(|r| r.mode == mode && r.producers == 16)
            .expect("both modes run the 16-producer point")
    };
    let coalesce = find(BatchMode::Coalesce);
    let continuous = find(BatchMode::Continuous);
    println!(
        "\n16 producers: continuous {:.0} req/s / ttft p99 {:.2} ms vs coalesce {:.0} req/s / ttft p99 {:.2} ms",
        continuous.rate, continuous.ttft_p99, coalesce.rate, coalesce.ttft_p99
    );
    if gate_enabled() {
        assert!(
            continuous.rate > coalesce.rate,
            "continuous batching must out-serve coalescing at 16 producers: {:.0} <= {:.0} req/s",
            continuous.rate,
            coalesce.rate
        );
        assert!(
            continuous.ttft_p99 < coalesce.ttft_p99,
            "continuous batching must cut p99 TTFT at 16 producers: {:.2} >= {:.2} ms",
            continuous.ttft_p99,
            coalesce.ttft_p99
        );
    } else {
        println!("bench gate disabled (ISLANDRUN_BENCH_GATE=off): comparison not enforced");
    }
}
