//! Socket-true end-to-end serving latency: submit → SSE-stream-to-terminal
//! through a real loopback `TcpListener` at 1, 4 and 16 keep-alive
//! connections, against the in-process enqueue→stream→resolve baseline at
//! the same concurrency — so the cost of the HTTP/1.1 boundary itself
//! (parse, auth, registry, chunked SSE relay) is measured directly rather
//! than inferred.
//!
//! Each connection runs a closed loop: POST one submit, read the ticket id,
//! then drain `GET /v1/stream/:id` to its terminal record and sample the
//! end-to-end wall time. The baseline drives `Orchestrator::enqueue` with
//! the identical request mix and drains the ticket's `TokenStream`
//! in-process. Samples land in pre-registered labeled histogram handles
//! (`bench_http_wall_ms{transport,connections}`) on the orchestrator's own
//! registry, and the reported percentiles are read back from the snapshots —
//! the same path `/metrics` exposes.
//!
//! CI hooks: `ISLANDRUN_BENCH_REQUESTS` overrides the total request count,
//! `ISLANDRUN_BENCH_JSON=<path>` writes the rows as a JSON artifact
//! (uploaded as `BENCH_http.json`), and `ISLANDRUN_BENCH_GATE=off` disables
//! the final overhead gate (socket p99 ≤ 3× in-process p99 at 16
//! connections) for smoke runs on noisy shared runners.

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::json::Json;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::server::http::client::HttpClient;
use islandrun::server::{Backend, HttpConfig, HttpServer, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::{priority_for, prompt_for, SensClass};
use islandrun::types::PriorityTier;
use islandrun::util::bench::{gate_enabled, write_json_artifact};
use islandrun::util::{Rng, Table};

fn total_requests() -> usize {
    std::env::var("ISLANDRUN_BENCH_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(2400)
}

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the bench measures transport + lifecycle overhead, not admission
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.serve_workers = 4;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

fn priority_label(p: PriorityTier) -> &'static str {
    match p {
        PriorityTier::Primary => "primary",
        PriorityTier::Secondary => "secondary",
        PriorityTier::Burstable => "burstable",
    }
}

fn submit_json(class: SensClass, rng: &mut Rng) -> Json {
    Json::obj(vec![
        ("prompt", Json::str(&prompt_for(class, rng))),
        ("priority", Json::str(priority_label(priority_for(class)))),
        ("deadline_ms", Json::num(1e12)),
    ])
}

/// Served count off the orchestrator's own resolution family — the socket
/// client only sees terminal SSE records, so the classification that both
/// transports share lives server-side.
fn served_count(orch: &Orchestrator) -> usize {
    orch.metrics
        .counter_children("requests_resolved")
        .into_iter()
        .filter(|(labels, _)| labels.first().map(|l| l.as_str()) == Some("served"))
        .map(|(_, v)| v as usize)
        .sum()
}

struct Point {
    transport: &'static str,
    connections: usize,
    rate: f64,
    p99: f64,
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = total_requests();
    println!("http_e2e — submit→stream-to-terminal over loopback TCP vs in-process (Sim)");
    println!("{cores} cores, {total} requests\n");

    let mut t = Table::new(
        "http_e2e — end-to-end wall time vs connection count (4 workers)",
        &["transport", "connections", "req/s", "p50 ms", "p99 ms", "served", "rejected"],
    );
    let mut json_rows = Vec::new();
    let mut points: Vec<Point> = Vec::new();
    for &connections in &[1usize, 4, 16] {
        for &transport in &["socket", "inproc"] {
            // the in-process baseline only needs the 16-way point for the
            // gate, plus 1-way for the table's single-stream reference
            if transport == "inproc" && connections == 4 {
                continue;
            }
            let orch = orchestrator(500 + connections as u64);
            let wall_vec = orch.metrics.histogram_vec(
                "bench_http_wall_ms",
                "bench: submit->terminal wall time (ms)",
                &["transport", "connections"],
            );
            let label_connections = connections.to_string();
            let wall_hist = wall_vec.with(&[transport, &label_connections]);
            let per = (total / connections).max(1);
            let attempted = connections * per;
            let server = if transport == "socket" {
                let grants: Vec<(String, String)> =
                    (0..connections).map(|c| (format!("bench-key-{c}"), format!("http-bench-{c}"))).collect();
                let config = HttpConfig { rate_per_sec: 1e9, burst: 1e9, ticket_capacity: 8192, ..HttpConfig::default() };
                Some(HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, config).expect("bind loopback"))
            } else {
                Arc::clone(&orch).start_queue();
                None
            };
            let addr = server.as_ref().map(|s| s.addr());
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = (0..connections)
                .map(|c| {
                    let orch = Arc::clone(&orch);
                    let wall_hist = wall_hist.clone();
                    std::thread::spawn(move || {
                        let mut rng = Rng::new(43 ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                        let mut errors = 0usize;
                        match addr {
                            Some(addr) => {
                                let key = format!("bench-key-{c}");
                                let mut client = HttpClient::connect(addr).expect("connect loopback");
                                for i in 0..per {
                                    let body = submit_json(class_for(i), &mut rng);
                                    let start = std::time::Instant::now();
                                    let ok = client
                                        .request("POST", "/v1/submit", Some(&key), Some(&body))
                                        .ok()
                                        .filter(|r| r.status == 200)
                                        .and_then(|r| r.json().as_ref().and_then(|j| j.get("ticket").as_i64()))
                                        .and_then(|id| {
                                            client.stream_events(&format!("/v1/stream/{id}"), Some(&key)).ok()
                                        })
                                        .is_some_and(|(status, _events)| status == 200);
                                    if ok {
                                        wall_hist.observe(start.elapsed().as_secs_f64() * 1e3);
                                    } else {
                                        errors += 1;
                                    }
                                }
                            }
                            None => {
                                let session = orch.open_session(&format!("http-bench-{c}"));
                                for i in 0..per {
                                    let class = class_for(i);
                                    let submit = SubmitRequest::new(prompt_for(class, &mut rng))
                                        .priority(priority_for(class))
                                        .deadline_ms(1e12);
                                    let start = std::time::Instant::now();
                                    let ticket = orch.enqueue(session, submit);
                                    for _event in ticket.stream() {}
                                    match ticket.wait() {
                                        Ok(_) => wall_hist.observe(start.elapsed().as_secs_f64() * 1e3),
                                        Err(_) => errors += 1,
                                    }
                                    orch.advance(5.0);
                                }
                            }
                        }
                        errors
                    })
                })
                .collect();
            let errors: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let wall = t0.elapsed().as_secs_f64();
            if let Some(server) = server {
                server.shutdown();
            }
            assert_eq!(errors, 0, "{transport}/{connections}: no request may be lost");
            assert_eq!(orch.audit.len(), attempted, "audit trail must cover every submission");
            assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);

            let rate = attempted as f64 / wall.max(1e-9);
            let snap = wall_hist.snapshot();
            assert_eq!(snap.count(), attempted as u64, "every request is sampled");
            let p50 = snap.p50();
            let p99 = snap.p99();
            let served = served_count(&orch);
            let rejected = attempted - served;
            t.row(&[
                transport.to_string(),
                connections.to_string(),
                format!("{rate:.0}"),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                served.to_string(),
                rejected.to_string(),
            ]);
            json_rows.push(vec![
                ("socket".to_string(), if transport == "socket" { 1.0 } else { 0.0 }),
                ("connections".to_string(), connections as f64),
                ("req_per_s".to_string(), rate),
                ("p50_ms".to_string(), p50),
                ("p99_ms".to_string(), p99),
                ("served".to_string(), served as f64),
                ("rejected".to_string(), rejected as f64),
            ]);
            points.push(Point { transport, connections, rate, p99 });
        }
    }
    t.print();
    write_json_artifact("http", &json_rows);

    // The overhead claim, gated: at 16 connections the socket boundary may
    // cost at most 3× the in-process p99. `ISLANDRUN_BENCH_GATE=off` skips
    // the assertion; the fields always land in the JSON artifact above.
    let find = |transport: &str| {
        points
            .iter()
            .find(|p| p.transport == transport && p.connections == 16)
            .expect("both transports run the 16-way point")
    };
    let socket = find("socket");
    let inproc = find("inproc");
    println!(
        "\n16-way: socket {:.0} req/s / p99 {:.3} ms vs in-process {:.0} req/s / p99 {:.3} ms ({:.2}x p99)",
        socket.rate,
        socket.p99,
        inproc.rate,
        inproc.p99,
        socket.p99 / inproc.p99.max(1e-9)
    );
    if gate_enabled() {
        assert!(
            socket.p99 <= 3.0 * inproc.p99,
            "socket boundary too expensive at 16 connections: p99 {:.3} ms > 3x in-process {:.3} ms",
            socket.p99,
            inproc.p99
        );
    } else {
        println!("bench gate disabled (ISLANDRUN_BENCH_GATE=off): overhead gate not enforced");
    }
}
