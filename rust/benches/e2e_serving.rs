//! E13 / Fig. 2 — end-to-end serving bench over the REAL PJRT engine:
//! throughput/latency through the full pipeline, batch-variant scaling, and
//! the dynamic-batcher policy ablation. Skips (cleanly) when artifacts/ is
//! absent.

use std::path::Path;
use std::time::Instant;

use islandrun::agents::mist::{Mist, Stage2};
use islandrun::config::{preset_personal_group, Config};
use islandrun::islands::executor::IslandExecutor;
use islandrun::runtime::Engine;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::paper_mix;
use islandrun::util::bench::{bench, report};
use islandrun::util::Table;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("e2e_serving: artifacts/ not built — skipping (run `make artifacts`)");
        return Ok(());
    }
    let engine = Engine::load(dir)?;
    let handle = engine.handle();

    // --- raw PJRT forward scaling across batch variants -------------------
    let mut fwd = Vec::new();
    for b in [1usize, 4, 8] {
        fwd.push(bench(&format!("lm forward b={b}"), 3, 30, || {
            handle.raw_forward(b).unwrap();
        }));
    }
    report("e2e_serving — raw TinyLM forward (one decode step)", &fwd);
    let per_row_b1 = fwd[0].mean_us;
    let per_row_b8 = fwd[2].mean_us / 8.0;
    println!(
        "batching efficiency: b=8 amortizes to {:.0}us/row vs {:.0}us at b=1 ({:.2}x)\n",
        per_row_b8,
        per_row_b1,
        per_row_b1 / per_row_b8
    );

    // --- generation throughput (decode loop) -------------------------------
    let prompts: Vec<String> = paper_mix(8, 1).into_iter().map(|i| i.request.prompt).collect();
    let t0 = Instant::now();
    let gens = handle.generate(prompts.clone(), 16)?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = gens.iter().map(|g| g.tokens_generated).sum();
    println!("batched generation: {tokens} tokens in {wall:.2}s = {:.1} tok/s\n", tokens as f64 / wall);

    // --- full pipeline over the real engine --------------------------------
    let islands = preset_personal_group();
    let mist = Mist::new(Stage2::Classifier(engine.handle()));
    let executor = IslandExecutor::new(engine.handle(), 7);
    let orch = Orchestrator::new(Config::default(), mist, Backend::Real { executor, islands }, 7);
    let session = orch.open_session("bench");
    let trace = paper_mix(32, 5);

    // batched submit: co-routed requests coalesce into the compiled PJRT
    // batch variants through Orchestrator::submit_many_requests
    let items: Vec<SubmitRequest> =
        trace.iter().map(|i| SubmitRequest::new(&i.request.prompt).priority(i.request.priority)).collect();
    let t0 = Instant::now();
    let mut latencies = Vec::new();
    for chunk in items.chunks(8) {
        for result in orch.submit_many_requests(session, chunk.to_vec()) {
            latencies.push(result?.latency_ms);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new("e2e_serving — full Fig. 2 pipeline (real engine, batched submit)", &["metric", "value"]);
    t.row(&["requests".into(), trace.len().to_string()]);
    t.row(&["throughput".into(), format!("{:.2} req/s", trace.len() as f64 / wall)]);
    t.row(&["p50 latency".into(), format!("{:.1} ms", islandrun::util::stats::percentile(&latencies, 0.5))]);
    t.row(&["p95 latency".into(), format!("{:.1} ms", islandrun::util::stats::percentile(&latencies, 0.95))]);
    t.print();

    // --- coordinator overhead: pipeline minus compute ----------------------
    let mist2 = Mist::heuristic();
    let route_only = bench("mist+route+session (no compute)", 20, 500, || {
        let r = islandrun::types::Request::new(1, &trace[0].request.prompt);
        std::hint::black_box(mist2.analyze(&r));
    });
    report("e2e_serving — coordinator-side cost (excludes PJRT compute)", &[route_only]);
    Ok(())
}
