//! E9 — MIST sanitization microbenchmarks: entity detection, forward τ,
//! backward φ⁻¹, and full history migration. Sanitization sits on the
//! trust-boundary crossing path, so its latency bounds the cross-tier
//! routing overhead.

use islandrun::agents::mist::entities;
use islandrun::agents::mist::sanitize::{sanitize_history, turn, PlaceholderMap};
use islandrun::types::Role;
use islandrun::util::bench::{bench, report};

const SHORT: &str = "patient john doe ssn 123-45-6789 diagnosed with diabetes in chicago";

fn long_history() -> Vec<islandrun::types::Turn> {
    let mut h = Vec::new();
    for i in 0..20 {
        h.push(turn(
            Role::User,
            &format!("turn {i}: patient jane smith mrn 4921{i} prescribed metformin 500 mg daily in berlin on 2024-03-1{}", i % 9),
        ));
        h.push(turn(Role::Assistant, &format!("noted for jane smith, adjusting the plan {i}")));
    }
    h
}

fn main() {
    let mut results = Vec::new();

    results.push(bench("detect entities (70B prompt)", 20, 2000, || {
        std::hint::black_box(entities::detect(SHORT));
    }));

    results.push(bench("sanitize short prompt", 20, 2000, || {
        let mut map = PlaceholderMap::new(1);
        std::hint::black_box(map.sanitize(SHORT, 0.4));
    }));

    let history = long_history();
    results.push(bench("sanitize 40-turn history", 5, 200, || {
        let mut map = PlaceholderMap::new(2);
        std::hint::black_box(sanitize_history(&history, 0.4, &mut map));
    }));

    // desanitize pass over a response full of placeholders
    let mut map = PlaceholderMap::new(3);
    let sanitized = map.sanitize(SHORT, 0.4);
    let response = format!("{sanitized} — recommend follow-up for the same case. {sanitized}");
    results.push(bench("desanitize response", 20, 2000, || {
        std::hint::black_box(map.desanitize(&response));
    }));

    report("sanitization — trust-boundary crossing costs", &results);

    // round-trip correctness under bench load (guard against optimizing away)
    let mut m = PlaceholderMap::new(9);
    let s = m.sanitize(SHORT, 0.4);
    assert!(PlaceholderMap::verify_clean(&s, 0.4));
    assert!(m.desanitize(&s).contains("john doe"));
    println!("PASS: round-trip integrity under bench configuration");
}
