//! E9 — MIST sanitization microbenchmarks: entity detection, forward τ,
//! backward φ⁻¹, and the per-session incremental history path. Sanitization
//! sits on the trust-boundary crossing path, so its latency bounds the
//! cross-tier routing overhead.
//!
//! The headline comparison is cold vs incremental on a 64-turn session:
//! the cold path scans every turn; the incremental path reuses the
//! per-level sanitized-history cache and scans only the outgoing prompt
//! (the newest-turn delta). Gated at ≥5x unless `ISLANDRUN_BENCH_GATE=off`
//! (the CI smoke job measures without gating). With
//! `ISLANDRUN_BENCH_JSON=<path>` the results land in `BENCH_sanitize.json`.

use islandrun::agents::mist::entities;
use islandrun::agents::mist::sanitize::PlaceholderMap;
use islandrun::server::Session;
use islandrun::util::bench::{bench, gate_enabled, report, write_json_artifact};

const SHORT: &str = "patient john doe ssn 123-45-6789 diagnosed with diabetes in chicago";
const PROMPT: &str = "patient jane smith asks about metformin in berlin";
const HISTORY_TURNS: usize = 64;

/// A 64-turn entity-rich session history (32 user/assistant pairs).
fn session_with_history(id: u64) -> Session {
    let mut s = Session::new(id, "bench", 0xBE9C ^ id);
    for i in 0..HISTORY_TURNS / 2 {
        s.record_turn(
            &format!(
                "turn {i}: patient jane smith mrn 4921{i} prescribed metformin 500 mg daily in berlin on 2024-03-1{}",
                i % 9
            ),
            &format!("noted for jane smith, adjusting the plan {i}"),
            1.0,
        );
    }
    s
}

/// One request's sanitize pass through the three-phase session API:
/// plan (read lock scope) → detect (no lock) → apply (write lock scope).
fn sanitize_pass(session: &mut Session, level: f64) -> usize {
    let snapshot = session.history.clone();
    let plan = session.plan_sanitize(level, &snapshot, PROMPT);
    let wire = plan.detect().apply(session);
    wire.history.len()
}

fn main() {
    let mut results = Vec::new();

    results.push(bench("detect entities (70B prompt)", 20, 2000, || {
        std::hint::black_box(entities::detect(SHORT));
    }));

    let short = bench("sanitize short prompt", 20, 2000, || {
        let mut map = PlaceholderMap::new(1);
        std::hint::black_box(map.sanitize(SHORT, 0.4));
    });
    results.push(short.clone());

    // cold: an empty cache forces a scan of the whole 64-turn history +
    // prompt. The session is prebuilt and only its cache is reset per
    // iteration, so the measurement is the sanitize pass itself, not
    // session construction (leaving the placeholder map warm makes "cold"
    // slightly cheaper — conservative for the speedup gate below).
    let mut cold_session = session_with_history(2);
    let cold = bench("sanitize 64-turn history (cold)", 5, 120, || {
        cold_session.sanitized = Default::default();
        std::hint::black_box(sanitize_pass(&mut cold_session, 0.4));
    });
    results.push(cold.clone());

    // incremental: the cache already covers all 64 turns; each request
    // scans only the outgoing prompt and reuses the cached prefix
    let mut warm = session_with_history(3);
    let _ = sanitize_pass(&mut warm, 0.4); // warm the 0.4-level cache
    assert_eq!(
        warm.sanitized.turns_at(0.4).map(|t| t.len()),
        Some(HISTORY_TURNS),
        "level cache must cover the full history before the incremental measurement"
    );
    let incremental = bench("sanitize 64-turn history (incremental)", 20, 2000, || {
        std::hint::black_box(sanitize_pass(&mut warm, 0.4));
    });
    results.push(incremental.clone());

    // failover path: cold-sanitize at 0.7, then hop down to 0.3 — the
    // second pass re-sanitizes the cached clean form (placeholders inert,
    // still O(covered)); the cache is reset per iteration, session reused
    let mut failover_session = session_with_history(4);
    let resplice = bench("cold@0.7 + failover resplice@0.3 (64 turns)", 5, 120, || {
        failover_session.sanitized = Default::default();
        sanitize_pass(&mut failover_session, 0.7);
        std::hint::black_box(sanitize_pass(&mut failover_session, 0.3));
    });
    results.push(resplice.clone());

    // desanitize pass over a response full of placeholders
    let mut map = PlaceholderMap::new(5);
    let sanitized = map.sanitize(SHORT, 0.4);
    let response = format!("{sanitized} — recommend follow-up for the same case. {sanitized}");
    results.push(bench("desanitize response", 20, 2000, || {
        std::hint::black_box(map.desanitize(&response));
    }));

    report("sanitization — trust-boundary crossing costs", &results);

    let speedup = if incremental.mean_us > 0.0 { cold.mean_us / incremental.mean_us } else { 0.0 };
    println!("\nincremental speedup over cold 64-turn sanitization: {speedup:.1}x");

    let json_rows: Vec<Vec<(String, f64)>> = vec![
        vec![
            ("turns".to_string(), HISTORY_TURNS as f64),
            ("cold_mean_us".to_string(), cold.mean_us),
            ("cold_p99_us".to_string(), cold.p99_us),
            ("incremental_mean_us".to_string(), incremental.mean_us),
            ("incremental_p99_us".to_string(), incremental.p99_us),
            ("resplice_mean_us".to_string(), resplice.mean_us),
            ("speedup_cold_over_incremental".to_string(), speedup),
        ],
        vec![
            ("turns".to_string(), 1.0),
            ("cold_mean_us".to_string(), short.mean_us),
            ("cold_p99_us".to_string(), short.p99_us),
        ],
    ];
    write_json_artifact("sanitize", &json_rows);

    // round-trip correctness under bench load (guard against optimizing away)
    let mut m = PlaceholderMap::new(9);
    let s = m.sanitize(SHORT, 0.4);
    assert!(PlaceholderMap::verify_clean(&s, 0.4));
    assert!(m.desanitize(&s).contains("john doe"));
    println!("PASS: round-trip integrity under bench configuration");

    if gate_enabled() {
        assert!(
            speedup >= 5.0,
            "incremental 64-turn sanitization must be >= 5x over cold, measured {speedup:.1}x"
        );
        println!("PASS: incremental path >= 5x over cold ({speedup:.1}x)");
    } else {
        println!("GATE OFF: measured {speedup:.1}x incremental speedup (smoke run, not asserted)");
    }
}
