//! Minimal, offline, API-compatible subset of the `once_cell` crate:
//! just `once_cell::sync::Lazy`, backed by `std::sync::OnceLock`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access. The default `F = fn() -> T`
    /// lets non-capturing closures coerce in `static` initializers, exactly
    /// like upstream once_cell.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static CALLS: AtomicUsize = AtomicUsize::new(0);
    static VALUE: Lazy<Vec<u32>> = Lazy::new(|| {
        CALLS.fetch_add(1, Ordering::SeqCst);
        vec![1, 2, 3]
    });

    #[test]
    fn initializes_once_under_threads() {
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(|| VALUE.iter().sum::<u32>()))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 6);
        }
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        assert_eq!(VALUE.len(), 3);
    }
}
