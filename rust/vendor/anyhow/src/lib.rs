//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! Implements exactly what this workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, and `?`-conversion from any
//! `std::error::Error + Send + Sync + 'static`. No backtraces, no context
//! chains, no downcasting.

use std::error::Error as StdError;
use std::fmt;

/// A boxed dynamic error with a `Display`-first presentation.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `anyhow::Result<T>` — second parameter defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Create an error from any boxed std error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket conversion below coherent (same trick as upstream
// anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error { inner: Box::new(error) }
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(s)
    }

    fn fails_fmt(id: u64) -> Result<()> {
        bail!("unknown session {id}")
    }

    fn fails_args(name: &str, n: usize) -> Result<()> {
        bail!("{} missing {n} items", name)
    }

    fn checks(n: usize) -> Result<usize> {
        ensure!(n > 0, "empty batch");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
        let _dbg = format!("{e:?}");
    }

    #[test]
    fn macros_format() {
        assert_eq!(fails_fmt(7).unwrap_err().to_string(), "unknown session 7");
        assert_eq!(fails_args("batch", 3).unwrap_err().to_string(), "batch missing 3 items");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_returns_ok_or_err() {
        assert_eq!(checks(2).unwrap(), 2);
        assert_eq!(checks(0).unwrap_err().to_string(), "empty batch");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn expr_form_accepts_display() {
        let e = anyhow!(std::io::Error::other("boom"));
        assert_eq!(e.to_string(), "boom");
    }
}
