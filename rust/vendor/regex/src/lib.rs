//! Minimal, offline, API-compatible subset of the `regex` crate.
//!
//! A recursive-descent parser plus a backtracking matcher with leftmost-first
//! semantics (the same observable match semantics as the real crate for the
//! feature subset below). Supported syntax — the union of everything the
//! IslandRun MIST patterns use:
//!
//! - literals, `.` (any char but `\n`), alternation `|`
//! - non-capturing groups `(?:...)`, inline flag groups `(?i:...)`, flag
//!   directives `(?i)` (scoped to the rest of the enclosing group), and
//!   plain `(...)` groups (treated as non-capturing; only group 0 exists)
//! - character classes `[...]` with ranges, negation `[^...]`, literal `-`
//!   at either end, and `\d \s \w` inside classes
//! - escapes `\d \D \s \S \w \W \b` and escaped metacharacters
//! - quantifiers `? * + {n} {n,} {n,m}` (greedy only)
//!
//! Offsets returned by [`Match::start`]/[`Match::end`] are byte offsets into
//! the original text, always on UTF-8 boundaries. A first-character bitmap
//! prunes scan positions so the O(|q|·m) MIST stage-1 sweep stays well under
//! the paper's 10 ms routing budget.

use std::borrow::Cow;
use std::fmt;

/// Pattern compilation error.
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex parse error: {}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex parse error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

// ---------------------------------------------------------------------------
// AST
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Named {
    Digit,
    NotDigit,
    Space,
    NotSpace,
    Word,
    NotWord,
}

impl Named {
    fn test(self, c: char) -> bool {
        match self {
            Named::Digit => c.is_ascii_digit(),
            Named::NotDigit => !c.is_ascii_digit(),
            Named::Space => c.is_whitespace(),
            Named::NotSpace => !c.is_whitespace(),
            Named::Word => is_word(c),
            Named::NotWord => !is_word(c),
        }
    }
}

#[derive(Clone, Debug, Default)]
struct ClassSet {
    negated: bool,
    ranges: Vec<(char, char)>,
    named: Vec<Named>,
}

impl ClassSet {
    fn raw(&self, c: char) -> bool {
        self.ranges.iter().any(|&(lo, hi)| lo <= c && c <= hi) || self.named.iter().any(|n| n.test(c))
    }

    fn contains(&self, c: char, icase: bool) -> bool {
        let mut hit = self.raw(c);
        if !hit && icase && c.is_ascii_alphabetic() {
            hit = self.raw(c.to_ascii_lowercase()) || self.raw(c.to_ascii_uppercase());
        }
        hit != self.negated
    }
}

#[derive(Clone, Debug)]
enum Node {
    Empty,
    Char { c: char, icase: bool },
    Class { set: ClassSet, icase: bool },
    Dot,
    WordBoundary,
    Concat(Vec<Node>),
    Alt(Vec<Node>),
    Repeat { node: Box<Node>, min: u32, max: Option<u32> },
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser {
    chars: Vec<char>,
    pos: usize,
    icase: bool,
}

impl Parser {
    fn err(&self, msg: &str) -> Error {
        Error { msg: format!("{} at pattern offset {}", msg, self.pos) }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<char> {
        self.chars.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn alt(&mut self) -> Result<Node, Error> {
        let mut branches = vec![self.concat()?];
        while self.peek() == Some('|') {
            self.pos += 1;
            branches.push(self.concat()?);
        }
        if branches.len() == 1 {
            Ok(branches.pop().expect("one branch"))
        } else {
            Ok(Node::Alt(branches))
        }
    }

    fn concat(&mut self) -> Result<Node, Error> {
        let mut items = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            let item = self.repeat_atom()?;
            if !matches!(item, Node::Empty) {
                items.push(item);
            }
        }
        match items.len() {
            0 => Ok(Node::Empty),
            1 => Ok(items.pop().expect("one item")),
            _ => Ok(Node::Concat(items)),
        }
    }

    fn repeat_atom(&mut self) -> Result<Node, Error> {
        let mut node = self.atom()?;
        loop {
            match self.peek() {
                Some('?') => {
                    self.pos += 1;
                    node = Node::Repeat { node: Box::new(node), min: 0, max: Some(1) };
                }
                Some('*') => {
                    self.pos += 1;
                    node = Node::Repeat { node: Box::new(node), min: 0, max: None };
                }
                Some('+') => {
                    self.pos += 1;
                    node = Node::Repeat { node: Box::new(node), min: 1, max: None };
                }
                Some('{') if self.peek2().map(|c| c.is_ascii_digit()).unwrap_or(false) => {
                    self.pos += 1;
                    let (min, max) = self.bounds()?;
                    node = Node::Repeat { node: Box::new(node), min, max };
                }
                _ => return Ok(node),
            }
        }
    }

    fn bounds(&mut self) -> Result<(u32, Option<u32>), Error> {
        let min = self.number()?;
        match self.bump() {
            Some('}') => Ok((min, Some(min))),
            Some(',') => {
                if self.peek() == Some('}') {
                    self.pos += 1;
                    Ok((min, None))
                } else {
                    let max = self.number()?;
                    if self.bump() != Some('}') {
                        return Err(self.err("expected '}' after repetition bounds"));
                    }
                    if max < min {
                        return Err(self.err("repetition max < min"));
                    }
                    Ok((min, Some(max)))
                }
            }
            _ => Err(self.err("malformed repetition bounds")),
        }
    }

    fn number(&mut self) -> Result<u32, Error> {
        let start = self.pos;
        while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected number"));
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse().map_err(|_| self.err("repetition count too large"))
    }

    fn atom(&mut self) -> Result<Node, Error> {
        match self.bump() {
            Some('(') => self.group(),
            Some('[') => self.class(),
            Some('\\') => self.escape(),
            Some('.') => Ok(Node::Dot),
            Some('^') | Some('$') => Err(self.err("anchors ^ and $ are not supported")),
            Some(c) => Ok(Node::Char { c, icase: self.icase }),
            None => Err(self.err("unexpected end of pattern")),
        }
    }

    fn group(&mut self) -> Result<Node, Error> {
        if self.peek() == Some('?') {
            self.pos += 1;
            // flag chars until ':' (scoped group) or ')' (directive)
            let mut icase_on = false;
            loop {
                match self.peek() {
                    Some('i') => {
                        icase_on = true;
                        self.pos += 1;
                    }
                    Some(':') => {
                        self.pos += 1;
                        let saved = self.icase;
                        if icase_on {
                            self.icase = true;
                        }
                        let inner = self.alt()?;
                        self.icase = saved;
                        if self.bump() != Some(')') {
                            return Err(self.err("unclosed group"));
                        }
                        return Ok(inner);
                    }
                    Some(')') => {
                        self.pos += 1;
                        // directive: flags apply to the rest of the
                        // enclosing group / pattern
                        if icase_on {
                            self.icase = true;
                        }
                        return Ok(Node::Empty);
                    }
                    _ => return Err(self.err("unsupported group flags (only (?i), (?i:), (?:) )")),
                }
            }
        }
        // plain group, treated as non-capturing; a (?i) directive inside is
        // scoped to this group, as in the real regex crate
        let saved = self.icase;
        let inner = self.alt()?;
        self.icase = saved;
        if self.bump() != Some(')') {
            return Err(self.err("unclosed group"));
        }
        Ok(inner)
    }

    fn escape(&mut self) -> Result<Node, Error> {
        let icase = self.icase;
        match self.bump() {
            Some('d') => Ok(class_node(Named::Digit, icase)),
            Some('D') => Ok(class_node(Named::NotDigit, icase)),
            Some('s') => Ok(class_node(Named::Space, icase)),
            Some('S') => Ok(class_node(Named::NotSpace, icase)),
            Some('w') => Ok(class_node(Named::Word, icase)),
            Some('W') => Ok(class_node(Named::NotWord, icase)),
            Some('b') => Ok(Node::WordBoundary),
            Some('n') => Ok(Node::Char { c: '\n', icase }),
            Some('t') => Ok(Node::Char { c: '\t', icase }),
            Some('r') => Ok(Node::Char { c: '\r', icase }),
            Some(c) if !c.is_alphanumeric() => Ok(Node::Char { c, icase }),
            Some(c) => Err(self.err(&format!("unsupported escape \\{c}"))),
            None => Err(self.err("dangling backslash")),
        }
    }

    fn class(&mut self) -> Result<Node, Error> {
        let mut set = ClassSet::default();
        if self.peek() == Some('^') {
            set.negated = true;
            self.pos += 1;
        }
        if self.peek() == Some(']') {
            set.ranges.push((']', ']'));
            self.pos += 1;
        }
        loop {
            let c = match self.bump() {
                None => return Err(self.err("unclosed character class")),
                Some(']') => break,
                Some('\\') => match self.bump() {
                    Some('d') => {
                        set.named.push(Named::Digit);
                        continue;
                    }
                    Some('s') => {
                        set.named.push(Named::Space);
                        continue;
                    }
                    Some('w') => {
                        set.named.push(Named::Word);
                        continue;
                    }
                    Some('n') => '\n',
                    Some('t') => '\t',
                    Some('r') => '\r',
                    Some(c) if !c.is_alphanumeric() => c,
                    Some(c) => return Err(self.err(&format!("unsupported class escape \\{c}"))),
                    None => return Err(self.err("dangling backslash in class")),
                },
                Some(c) => c,
            };
            // range if followed by '-' and a closing element that is not ']'
            if self.peek() == Some('-') && self.peek2().is_some() && self.peek2() != Some(']') {
                self.pos += 1; // consume '-'
                let hi = match self.bump() {
                    Some('\\') => self.bump().ok_or_else(|| self.err("dangling backslash in class"))?,
                    Some(h) => h,
                    None => return Err(self.err("unclosed character class")),
                };
                if hi < c {
                    return Err(self.err("invalid class range"));
                }
                set.ranges.push((c, hi));
            } else {
                set.ranges.push((c, c));
            }
        }
        Ok(Node::Class { set, icase: self.icase })
    }
}

fn class_node(named: Named, icase: bool) -> Node {
    Node::Class { set: ClassSet { negated: false, ranges: Vec::new(), named: vec![named] }, icase }
}

// ---------------------------------------------------------------------------
// First-character filter
// ---------------------------------------------------------------------------

/// Conservative over-approximation of the characters a match can start with.
#[derive(Clone, Debug)]
struct FirstSet {
    ascii: [bool; 128],
    /// true => any non-ASCII char may start a match
    non_ascii: bool,
}

impl FirstSet {
    fn all() -> FirstSet {
        FirstSet { ascii: [true; 128], non_ascii: true }
    }

    fn none() -> FirstSet {
        FirstSet { ascii: [false; 128], non_ascii: false }
    }

    fn add_char(&mut self, c: char, icase: bool) {
        if (c as u32) < 128 {
            self.ascii[c as usize] = true;
            if icase {
                self.ascii[c.to_ascii_lowercase() as usize] = true;
                self.ascii[c.to_ascii_uppercase() as usize] = true;
            }
        } else {
            self.non_ascii = true;
        }
    }

    fn add_named(&mut self, n: Named) {
        match n {
            Named::Digit => {
                for c in b'0'..=b'9' {
                    self.ascii[c as usize] = true;
                }
            }
            Named::Space => {
                for c in [b' ', b'\t', b'\n', b'\r', 0x0B, 0x0C] {
                    self.ascii[c as usize] = true;
                }
                self.non_ascii = true; // unicode spaces
            }
            Named::Word => {
                for c in 0..128u8 {
                    if (c as char).is_ascii_alphanumeric() || c == b'_' {
                        self.ascii[c as usize] = true;
                    }
                }
                self.non_ascii = true; // unicode word chars
            }
            // negated classes match almost everything
            Named::NotDigit | Named::NotSpace | Named::NotWord => {
                *self = FirstSet::all();
            }
        }
    }

    fn test(&self, c: char) -> bool {
        if (c as u32) < 128 {
            self.ascii[c as usize]
        } else {
            self.non_ascii
        }
    }
}

/// Accumulate the first set of `node` into `fs`; returns true when `node`
/// can match the empty string (so scanning must continue to the next item).
fn first_of(node: &Node, fs: &mut FirstSet) -> bool {
    match node {
        Node::Empty | Node::WordBoundary => true,
        Node::Char { c, icase } => {
            fs.add_char(*c, *icase);
            false
        }
        Node::Dot => {
            *fs = FirstSet::all();
            false
        }
        Node::Class { set, icase } => {
            if set.negated {
                *fs = FirstSet::all();
            } else {
                for &(lo, hi) in &set.ranges {
                    let mut c = lo;
                    loop {
                        fs.add_char(c, *icase);
                        if c >= hi || (c as u32) >= 128 {
                            if (hi as u32) >= 128 {
                                fs.non_ascii = true;
                            }
                            break;
                        }
                        c = char::from_u32(c as u32 + 1).unwrap_or(hi);
                    }
                }
                for &n in &set.named {
                    fs.add_named(n);
                }
            }
            false
        }
        Node::Concat(items) => {
            for item in items {
                if !first_of(item, fs) {
                    return false;
                }
            }
            true
        }
        Node::Alt(branches) => {
            let mut nullable = false;
            for b in branches {
                nullable |= first_of(b, fs);
            }
            nullable
        }
        Node::Repeat { node, min, .. } => {
            let inner_nullable = first_of(node, fs);
            *min == 0 || inner_nullable
        }
    }
}

// ---------------------------------------------------------------------------
// Matcher
// ---------------------------------------------------------------------------

struct Input<'t> {
    text: &'t str,
    chars: Vec<char>,
    /// byte offset of each char, plus a final entry == text.len()
    byte_pos: Vec<usize>,
}

impl<'t> Input<'t> {
    fn decode(text: &'t str) -> Input<'t> {
        let mut chars = Vec::with_capacity(text.len());
        let mut byte_pos = Vec::with_capacity(text.len() + 1);
        for (i, c) in text.char_indices() {
            byte_pos.push(i);
            chars.push(c);
        }
        byte_pos.push(text.len());
        Input { text, chars, byte_pos }
    }
}

fn m_node(node: &Node, inp: &Input<'_>, pos: usize, cont: &mut dyn FnMut(usize) -> bool) -> bool {
    match node {
        Node::Empty => cont(pos),
        Node::Char { c, icase } => match inp.chars.get(pos) {
            Some(&t) if t == *c || (*icase && t.eq_ignore_ascii_case(c)) => cont(pos + 1),
            _ => false,
        },
        Node::Class { set, icase } => match inp.chars.get(pos) {
            Some(&t) if set.contains(t, *icase) => cont(pos + 1),
            _ => false,
        },
        Node::Dot => match inp.chars.get(pos) {
            Some(&t) if t != '\n' => cont(pos + 1),
            _ => false,
        },
        Node::WordBoundary => {
            let before = pos > 0 && is_word(inp.chars[pos - 1]);
            let after = pos < inp.chars.len() && is_word(inp.chars[pos]);
            if before != after {
                cont(pos)
            } else {
                false
            }
        }
        Node::Concat(nodes) => m_seq(nodes, inp, pos, cont),
        Node::Alt(branches) => {
            for b in branches {
                if m_node(b, inp, pos, &mut *cont) {
                    return true;
                }
            }
            false
        }
        Node::Repeat { node, min, max } => m_repeat(node, *min, *max, inp, pos, 0, cont),
    }
}

fn m_seq(nodes: &[Node], inp: &Input<'_>, pos: usize, cont: &mut dyn FnMut(usize) -> bool) -> bool {
    match nodes.split_first() {
        None => cont(pos),
        Some((first, rest)) => m_node(first, inp, pos, &mut |p| m_seq(rest, inp, p, &mut *cont)),
    }
}

fn m_repeat(
    node: &Node,
    min: u32,
    max: Option<u32>,
    inp: &Input<'_>,
    pos: usize,
    count: u32,
    cont: &mut dyn FnMut(usize) -> bool,
) -> bool {
    // greedy: try one more iteration first, then fall back to the rest
    if max.map_or(true, |m| count < m) {
        let more = m_node(node, inp, pos, &mut |p| {
            // guard against zero-width repetition loops
            if p == pos {
                false
            } else {
                m_repeat(node, min, max, inp, p, count + 1, &mut *cont)
            }
        });
        if more {
            return true;
        }
    }
    if count >= min {
        cont(pos)
    } else {
        false
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// A compiled pattern.
#[derive(Clone, Debug)]
pub struct Regex {
    pattern: String,
    node: Node,
    first: FirstSet,
    can_match_empty: bool,
}

/// A single match: byte offsets into the searched text.
#[derive(Clone, Copy, Debug)]
pub struct Match<'t> {
    text: &'t str,
    start: usize,
    end: usize,
}

impl<'t> Match<'t> {
    pub fn start(&self) -> usize {
        self.start
    }

    pub fn end(&self) -> usize {
        self.end
    }

    pub fn as_str(&self) -> &'t str {
        &self.text[self.start..self.end]
    }
}

/// Capture groups of a match. Only group 0 (the whole match) exists in this
/// subset.
pub struct Captures<'t> {
    m: Match<'t>,
}

impl<'t> Captures<'t> {
    pub fn get(&self, i: usize) -> Option<Match<'t>> {
        if i == 0 {
            Some(self.m)
        } else {
            None
        }
    }
}

/// Iterator over non-overlapping matches.
pub struct Matches<'r, 't> {
    re: &'r Regex,
    inp: Input<'t>,
    next_char: usize,
}

impl<'r, 't> Iterator for Matches<'r, 't> {
    type Item = Match<'t>;

    fn next(&mut self) -> Option<Match<'t>> {
        if self.next_char > self.inp.chars.len() {
            return None;
        }
        let (s, e) = self.re.find_in(&self.inp, self.next_char)?;
        self.next_char = if e > s { e } else { s + 1 };
        Some(Match { text: self.inp.text, start: self.inp.byte_pos[s], end: self.inp.byte_pos[e] })
    }
}

impl Regex {
    pub fn new(pattern: &str) -> Result<Regex, Error> {
        let mut parser = Parser { chars: pattern.chars().collect(), pos: 0, icase: false };
        let node = parser.alt()?;
        if parser.pos != parser.chars.len() {
            return Err(parser.err("unexpected ')'"));
        }
        let mut first = FirstSet::none();
        let can_match_empty = first_of(&node, &mut first);
        if can_match_empty {
            first = FirstSet::all();
        }
        Ok(Regex { pattern: pattern.to_string(), node, first, can_match_empty })
    }

    pub fn as_str(&self) -> &str {
        &self.pattern
    }

    /// Leftmost match end for an anchored attempt at `start`, if any.
    fn match_at(&self, inp: &Input<'_>, start: usize) -> Option<usize> {
        let mut end = None;
        m_node(&self.node, inp, start, &mut |p| {
            end = Some(p);
            true
        });
        end
    }

    fn find_in(&self, inp: &Input<'_>, from: usize) -> Option<(usize, usize)> {
        for s in from..=inp.chars.len() {
            if s < inp.chars.len() {
                if !self.first.test(inp.chars[s]) {
                    continue;
                }
            } else if !self.can_match_empty {
                break;
            }
            if let Some(e) = self.match_at(inp, s) {
                return Some((s, e));
            }
        }
        None
    }

    pub fn is_match(&self, text: &str) -> bool {
        self.find(text).is_some()
    }

    pub fn find<'t>(&self, text: &'t str) -> Option<Match<'t>> {
        let inp = Input::decode(text);
        let (s, e) = self.find_in(&inp, 0)?;
        Some(Match { text, start: inp.byte_pos[s], end: inp.byte_pos[e] })
    }

    pub fn find_iter<'r, 't>(&'r self, text: &'t str) -> Matches<'r, 't> {
        Matches { re: self, inp: Input::decode(text), next_char: 0 }
    }

    /// Replace every match using the replacement closure. Returns borrowed
    /// text unchanged when nothing matched.
    pub fn replace_all<'t, F, S>(&self, text: &'t str, mut rep: F) -> Cow<'t, str>
    where
        F: FnMut(&Captures<'t>) -> S,
        S: AsRef<str>,
    {
        let inp = Input::decode(text);
        let mut out = String::new();
        let mut last_byte = 0usize;
        let mut from = 0usize;
        let mut any = false;
        while from <= inp.chars.len() {
            let Some((s, e)) = self.find_in(&inp, from) else { break };
            any = true;
            let (bs, be) = (inp.byte_pos[s], inp.byte_pos[e]);
            out.push_str(&text[last_byte..bs]);
            let caps = Captures { m: Match { text, start: bs, end: be } };
            out.push_str(rep(&caps).as_ref());
            last_byte = be;
            from = if e > s { e } else { s + 1 };
        }
        if !any {
            return Cow::Borrowed(text);
        }
        out.push_str(&text[last_byte..]);
        Cow::Owned(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans(re: &str, text: &str) -> Vec<(usize, usize)> {
        let re = Regex::new(re).unwrap();
        re.find_iter(text).map(|m| (m.start(), m.end())).collect()
    }

    fn first_match(re: &str, text: &str) -> Option<String> {
        let re = Regex::new(re).unwrap();
        re.find(text).map(|m| m.as_str().to_string())
    }

    #[test]
    fn literals_and_leftmost() {
        assert_eq!(first_match("abc", "xxabcyy"), Some("abc".into()));
        assert_eq!(first_match("abc", "ab"), None);
        assert_eq!(spans("a", "banana"), vec![(1, 2), (3, 4), (5, 6)]);
    }

    #[test]
    fn classes_ranges_and_negation() {
        assert_eq!(first_match("[a-c]+", "zzabccq"), Some("abcc".into()));
        assert_eq!(first_match("[^0-9]+", "12ab34"), Some("ab".into()));
        // literal '-' at either end, ']' first
        assert_eq!(first_match("[-. ]", "a-b"), Some("-".into()));
        assert_eq!(first_match("[a-z .'-]+", "o'neil-smith jr"), Some("o'neil-smith jr".into()));
        assert_eq!(first_match("[]a]+", "]a]"), Some("]a]".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(first_match(r"\d{3}", "ab1234"), Some("123".into()));
        assert_eq!(first_match(r"\s+", "a \t b"), Some(" \t ".into()));
        assert_eq!(first_match(r"\S{2,}", "a bc!d e"), Some("bc!d".into()));
        assert_eq!(first_match(r"\w+", "!hi_9!"), Some("hi_9".into()));
        assert_eq!(first_match(r"\.", "a.b"), Some(".".into()));
        assert_eq!(first_match(r"\+\d{1,3}", "+442"), Some("+442".into()));
    }

    #[test]
    fn quantifiers_greedy_with_backtracking() {
        assert_eq!(first_match(r"a{2,3}", "aaaa"), Some("aaa".into()));
        assert_eq!(first_match(r"ab?c", "ac"), Some("ac".into()));
        assert_eq!(first_match(r"ab?c", "abc"), Some("abc".into()));
        // backtracking through a greedy class
        assert_eq!(first_match(r"[a-z0-9.-]+\.[a-z]{2,}", "host.example.com!"), Some("host.example.com".into()));
        assert_eq!(first_match(r"\d{4}[- ]?\d{4}", "4111 1111"), Some("4111 1111".into()));
        assert_eq!(first_match(r"\d{4}[- ]?\d{4}", "41111111"), Some("41111111".into()));
    }

    #[test]
    fn alternation_prefers_left_then_backtracks() {
        // "st" preferred, but \b forces backtracking into "street"
        assert_eq!(first_match(r"(?:st|street)\b", "street"), Some("street".into()));
        assert_eq!(first_match(r"(?:st|street)\b", "st "), Some("st".into()));
    }

    #[test]
    fn word_boundaries() {
        assert_eq!(first_match(r"\bcat\b", "a cat sat"), Some("cat".into()));
        assert_eq!(first_match(r"\bcat\b", "concatenate"), None);
        assert_eq!(first_match(r"\bcat\b", "cat"), Some("cat".into()));
        assert_eq!(first_match(r"\b\d{3}-\d{2}-\d{4}\b", "ssn 123-45-6789."), Some("123-45-6789".into()));
        assert_eq!(first_match(r"\b\d{3}-\d{2}-\d{4}\b", "x123-45-6789"), None);
    }

    #[test]
    fn case_insensitive_flag_forms() {
        assert_eq!(first_match(r"(?i)patient", "The PATIENT file"), Some("PATIENT".into()));
        assert_eq!(first_match(r"(?i)\b[a-z]+\b", "HELLO"), Some("HELLO".into()));
        // group-scoped
        assert_eq!(first_match(r"(?i:mrn)\s*\d+", "MRN 123"), Some("MRN 123".into()));
        // directive scoped to rest of pattern after an alternation branch
        let re = Regex::new(r"\bAAA\b|(?i)\baccount\b").unwrap();
        assert!(re.is_match("my ACCOUNT here"));
        assert!(!re.is_match("my aaa here"), "first branch stays case-sensitive");
        // a directive inside a plain group must not leak past the group
        let re2 = Regex::new(r"(a(?i)b)c").unwrap();
        assert!(re2.is_match("aBc"));
        assert!(!re2.is_match("abC"), "(?i) is scoped to its enclosing group");
    }

    #[test]
    fn groups_and_nesting() {
        assert_eq!(first_match(r"(?:ab)+", "ababab!"), Some("ababab".into()));
        assert_eq!(first_match(r"(?:[0-9a-f]{1,4}:){3,7}[0-9a-f]{1,4}", "fe80:0:0:1"), Some("fe80:0:0:1".into()));
        assert_eq!(
            first_match(r"\b(?:last\s+\w+day|on\s+(?:mon|fri)day)\b", "see you on friday ok"),
            Some("on friday".into())
        );
    }

    #[test]
    fn byte_offsets_are_utf8_safe() {
        let text = "müller met JOHN";
        let re = Regex::new(r"(?i)\bjohn\b").unwrap();
        let m = re.find(text).unwrap();
        assert_eq!(m.as_str(), "JOHN");
        assert_eq!(&text[m.start()..m.end()], "JOHN");
        // non-ASCII word chars count for \b
        assert_eq!(first_match(r"\bller\b", "müller"), None);
    }

    #[test]
    fn replace_all_with_closure() {
        let re = Regex::new(r"\[[A-Z][A-Z_]*_\d+\]").unwrap();
        let out = re.replace_all("ask [PERSON_7] and [MEDICAL_CONDITION_123] now", |caps: &Captures<'_>| {
            let p = caps.get(0).unwrap().as_str();
            format!("<{p}>")
        });
        assert_eq!(out.into_owned(), "ask <[PERSON_7]> and <[MEDICAL_CONDITION_123]> now");
        // no match => borrowed passthrough
        let re2 = Regex::new(r"zzz").unwrap();
        assert!(matches!(re2.replace_all("nothing here", |_| "x"), Cow::Borrowed(_)));
    }

    #[test]
    fn find_iter_non_overlapping() {
        assert_eq!(spans(r"\d{2}", "123456"), vec![(0, 2), (2, 4), (4, 6)]);
        let text = "a@b.co and c@d.org";
        let re = Regex::new(r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b").unwrap();
        let found: Vec<&str> = re.find_iter(text).map(|m| m.as_str()).collect();
        assert_eq!(found, vec!["a@b.co", "c@d.org"]);
    }

    #[test]
    fn rejects_unsupported_syntax() {
        assert!(Regex::new(r"a(").is_err());
        assert!(Regex::new(r"[a-").is_err());
        assert!(Regex::new(r"^start").is_err());
        assert!(Regex::new(r"a{3,1}").is_err());
        assert!(Regex::new(r"\q").is_err());
    }

    /// Every production pattern used by the MIST stage-1 sweep, the entity
    /// detector and the sanitizer must compile here and agree on canonical
    /// positive/negative examples.
    #[test]
    fn islandrun_pattern_corpus() {
        let cases: &[(&str, &str, Option<&str>)] = &[
            (r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b", "mail X@Y.ORG now", Some("X@Y.ORG")),
            (r"\b\d{3}[-. ]\d{3}[-. ]\d{4}\b", "call 555-123-4567 soon", Some("555-123-4567")),
            (r"\+\d{1,3}[ -]?\d{2,4}[ -]?\d{3,4}[ -]?\d{3,4}\b", "+1 415 555 0199", Some("+1 415 555 0199")),
            (r"\b\d{3}-\d{2}-\d{4}\b", "ssn 123-45-6789 x", Some("123-45-6789")),
            (r"\b(?:\d{1,3}\.){3}\d{1,3}\b", "ip 10.0.0.12 up", Some("10.0.0.12")),
            (r"(?i)\b(?:[0-9a-f]{1,4}:){3,7}[0-9a-f]{1,4}\b", "fe80:1:2:3:4", Some("fe80:1:2:3:4")),
            (r"(?i)\b(?:[0-9a-f]{2}:){5}[0-9a-f]{2}\b", "mac 0A:1b:2c:3d:4e:5f!", Some("0A:1b:2c:3d:4e:5f")),
            (r"(?i)\bpassport\s*(?:no\.?|number)?\s*[:#]?\s*[a-z]?\d{7,9}\b", "passport no: X1234567", Some("passport no: X1234567")),
            (r"(?i)\b(?:driver'?s?\s+licen[sc]e|dl)\s*[:#]?\s*[a-z]?\d{6,9}\b", "driver's license 1234567", Some("driver's license 1234567")),
            (r"(?i)\blicense\s+plate\s*[:#]?\s*[a-z0-9-]{5,8}\b", "license plate AB-123C", Some("license plate AB-123C")),
            (r"(?i)\b(?:dob|date\s+of\s+birth)\s*[:#]?\s*\d{1,4}[-/]\d{1,2}[-/]\d{1,4}\b", "dob 1990/01/02", Some("dob 1990/01/02")),
            (r"(?i)\b\d{1,5}\s+[a-z]+\s+(?:st|street|ave|avenue|rd|road|blvd|lane|ln|dr|drive)\b", "at 10 main street,", Some("10 main street")),
            (r"\b\d{5}-\d{4}\b", "zip 94110-1234", Some("94110-1234")),
            (r"-?\d{1,3}\.\d{4,},\s*-?\d{1,3}\.\d{4,}", "at 37.7749,-122.4194", Some("37.7749,-122.4194")),
            (r"\b\d{4}\s\d{4}\s\d{4}\b", "id 1234 5678 9012.", Some("1234 5678 9012")),
            (r"(?i)\bnational\s+id\s*[:#]?\s*\d{6,12}\b", "national id 123456789", Some("national id 123456789")),
            (r"(?i)\bmy\s+(?:name|username)\s+is\s+[a-z][a-z .'-]{2,40}\b", "my name is jane doe", Some("my name is jane doe")),
            (r"\b(?:sk|pk|api)[-_](?:live|test)?[-_]?[A-Za-z0-9]{16,}\b", "key sk-live_ABCDEF0123456789xyz", Some("sk-live_ABCDEF0123456789xyz")),
            (r"(?i)\bpassword\s*[:=]\s*\S{6,}", "password: hunter2secret", Some("password: hunter2secret")),
            (r"ssh-(?:rsa|ed25519)\s+[A-Za-z0-9+/=]{40,}", "ssh-rsa AAAAB3NzaC1yc2EAAAADAQABAAABAQClongkeydata00", None),
            (r"(?i)\bpatient\b", "The Patient waits", Some("Patient")),
            (r"(?i)\bmrn\s*[:#]?\s*\d{4,10}\b", "MRN: 482910", Some("MRN: 482910")),
            (r"(?i)\b[a-tv-z]\d{2}(?:\.\d{1,4})?\b\s*(?:code|diagnos)", "E11.9 code", Some("E11.9 code")),
            (r"(?i)\bdiagnos(?:is|ed|tic)\b", "was diagnosed with", Some("diagnosed")),
            (r"(?i)\bprescri(?:bed?|ption)\b", "prescribed rest", Some("prescribed")),
            (r"(?i)\b\d+\s*(?:mg|mcg|ml|units?)\s+(?:daily|twice|bid|tid|qid|per\s+day)\b", "500 mg daily dose", Some("500 mg daily")),
            (r"\b\d{2,3}/\d{2,3}\s*(?:mmhg|bp)\b", "at 120/80 bp today", Some("120/80 bp")),
            (r"(?i)\b(?:glucose|cholesterol|a1c|creatinine)\s+(?:level|result)s?\b", "glucose levels high", Some("glucose levels")),
            (r"(?i)\bdiabet(?:es|ic)\b", "diabetic patient", Some("diabetic")),
            (r"(?i)\b(?:cancer|oncolog|chemotherapy)\b", "chemotherapy ward", Some("chemotherapy")),
            (r"(?i)\bhiv(?:\s+positive)?\b", "hiv positive result", Some("hiv positive")),
            (r"(?i)\b(?:depression|anxiety\s+disorder|schizophrenia|bipolar)\b", "anxiety disorder care", Some("anxiety disorder")),
            (r"(?i)\bsymptoms?\s+(?:of|include|analysis)\b", "symptoms of flu", Some("symptoms of")),
            (r"(?i)\btreatment\s+(?:options?|plan)\b", "Treatment options for", Some("Treatment options")),
            (r"(?i)\b(?:member|policy)\s+id\s*[:#]?\s*[a-z0-9]{6,14}\b", "member id AB12345", Some("member id AB12345")),
            (r"\b4\d{3}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b", "card 4111-1111-1111-1234 ok", Some("4111-1111-1111-1234")),
            (r"\b5[1-5]\d{2}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b", "mc 5500 0000 0000 0004", Some("5500 0000 0000 0004")),
            (r"\b3[47]\d{2}[- ]?\d{6}[- ]?\d{5}\b", "amex 3782 822463 10005", Some("3782 822463 10005")),
            (r"(?i)\bcvv2?\s*[:#]?\s*\d{3,4}\b", "cvv: 123", Some("cvv: 123")),
            (r"\b[A-Z]{2}\d{2}[A-Z0-9]{11,30}\b", "iban DE89370400440532013000", Some("DE89370400440532013000")),
            (r"(?i)\bswift\s*(?:code)?\s*[:#]?\s*[a-z]{6}[a-z0-9]{2,5}\b", "swift code DEUTDEFF", Some("swift code DEUTDEFF")),
            (r"(?i)\brouting\s*(?:no\.?|number)?\s*[:#]?\s*\d{9}\b", "routing number 021000021", Some("routing number 021000021")),
            (r"(?i)\baccount\s*(?:no\.?|number)?\s*[:#]?\s*\d{8,12}\b", "account 1234567890", Some("account 1234567890")),
            (r"(?i)\bwire\s+transfer\b", "a Wire Transfer now", Some("Wire Transfer")),
            (r"(?i)\bsalary\s+(?:review|of|is)\b", "salary of 100k", Some("salary of")),
            (r"\b(?:bc1|[13])[a-km-zA-HJ-NP-Z1-9]{25,42}\b", "pay 1A1zP1eP5QGefi2DMPTfTL5SLmv7DivfNa now", Some("1A1zP1eP5QGefi2DMPTfTL5SLmv7DivfNa")),
            (r"\b\d{2}-\d{7}\b", "ein 12-3456789.", Some("12-3456789")),
            (r"(?i)\b\d{1,3}[- ]?year[- ]?old\b", "a 45-year-old man", Some("45-year-old")),
            (r"(?i)\b\d{1,4}[-/]\d{1,2}[-/]\d{1,4}\b", "on 2024-01-05 we", Some("2024-01-05")),
            (r"\[[A-Z][A-Z_]*_\d+\]", "see [LOCATION_42] there", Some("[LOCATION_42]")),
        ];
        for (pattern, text, want) in cases {
            let re = Regex::new(pattern).unwrap_or_else(|e| panic!("pattern {pattern}: {e}"));
            let got = re.find(text).map(|m| m.as_str().to_string());
            match want {
                Some(w) => assert_eq!(got.as_deref(), Some(*w), "pattern {pattern} on {text:?}"),
                None => assert!(got.is_some(), "pattern {pattern} should match somewhere in {text:?}"),
            }
        }
    }

    #[test]
    fn clean_text_matches_nothing_sensitive() {
        let patterns = [
            r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b",
            r"\b\d{3}-\d{2}-\d{4}\b",
            r"(?i)\bpatient\b",
            r"\b4\d{3}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b",
            r"(?i)\b\d{1,5}\s+[a-z]+\s+(?:st|street|ave|avenue|rd|road|blvd|lane|ln|dr|drive)\b",
        ];
        for p in patterns {
            let re = Regex::new(p).unwrap();
            for text in ["what is the capital of france", "explain how rust ownership works", "write a haiku about islands"] {
                assert!(!re.is_match(text), "{p} wrongly matched {text:?}");
            }
        }
    }

    #[test]
    fn long_input_performance_smoke() {
        // the MIST bench scans ~4 KB prompts through ~50 patterns; one
        // pattern over 16 KB must finish fast (and not blow the stack)
        let text = "patient data ".repeat(1300);
        let re = Regex::new(r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b").unwrap();
        let t0 = std::time::Instant::now();
        assert!(!re.is_match(&text));
        assert!(t0.elapsed().as_millis() < 500, "too slow: {:?}", t0.elapsed());
        let re2 = Regex::new(r"[A-Za-z0-9+/=]{40,}").unwrap();
        let b64 = "Ab9".repeat(400);
        assert!(re2.is_match(&b64));
    }
}
