//! Hand-rolled Rust lexer: classifies string/char literals and comments so
//! the rule engine can work on a "code view" with non-code bytes blanked out.
//!
//! The lexer only needs to be right about *where literals and comments
//! start and end* — it never interprets code. It handles the delimiters
//! that matter for that job: escaped strings, byte strings, raw strings
//! with arbitrary `#` fences (`r#"..."#`), nested block comments, char
//! literals (including multi-byte chars like `'é'`), and the char-vs-
//! lifetime ambiguity (`'a'` vs `<'a>`). All scanning is byte-wise; every
//! token boundary lands on an ASCII delimiter, so byte offsets are always
//! char boundaries and UTF-8 identifiers pass through untouched.

/// What a non-code span is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Str,
    Char,
    LineComment,
    BlockComment,
}

/// Non-code spans of `src` as `(kind, start, end)` byte ranges, in order.
pub fn lex(src: &str) -> Vec<(TokKind, usize, usize)> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n {
            if b[i + 1] == b'/' {
                let j = memfind(b, b"\n", i).unwrap_or(n);
                toks.push((TokKind::LineComment, i, j));
                i = j;
                continue;
            }
            if b[i + 1] == b'*' {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                toks.push((TokKind::BlockComment, i, j));
                i = j;
                continue;
            }
        }
        if c == b'"' {
            let j = scan_escaped_string(b, i);
            toks.push((TokKind::Str, i, j));
            i = j;
            continue;
        }
        if c == b'r' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') {
            // raw string r"..." / r#"..."# (any fence width), or a raw
            // identifier r#ident, which is not a string at all
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                let mut close = Vec::with_capacity(hashes + 1);
                close.push(b'"');
                close.resize(hashes + 1, b'#');
                let k = match memfind(b, &close, j + 1) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                toks.push((TokKind::Str, i, k));
                i = k;
                continue;
            }
            i += 1;
            continue;
        }
        if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
            let j = scan_escaped_string(b, i + 1);
            toks.push((TokKind::Str, i, j));
            i = j;
            continue;
        }
        if c == b'\'' {
            // char literal or lifetime
            if i + 1 < n && b[i + 1] == b'\\' {
                // escaped char: scan to the closing quote
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                toks.push((TokKind::Char, i, (j + 1).min(n)));
                i = (j + 1).min(n);
                continue;
            }
            // one char (possibly multi-byte) followed by a closing quote?
            if let Some(ch) = src[i + 1..].chars().next() {
                let k = i + 1 + ch.len_utf8();
                if k < n && b[k] == b'\'' {
                    toks.push((TokKind::Char, i, k + 1));
                    i = k + 1;
                    continue;
                }
            }
            // lifetime: skip just the quote
            i += 1;
            continue;
        }
        i += 1;
    }
    toks
}

fn scan_escaped_string(b: &[u8], open: usize) -> usize {
    let n = b.len();
    let mut j = open + 1;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

fn memfind(haystack: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= haystack.len() {
        return None;
    }
    haystack[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

/// `src` with non-code spans blanked to spaces (newlines kept, so byte
/// offsets and line numbers are identical to the original). With
/// `keep_strings`, string/char literals survive — that view is used by the
/// metric-name rule, which must read literals but not comments.
pub fn blank(src: &str, keep_strings: bool) -> String {
    let mut out = src.as_bytes().to_vec();
    for (kind, s, e) in lex(src) {
        if keep_strings && matches!(kind, TokKind::Str | TokKind::Char) {
            continue;
        }
        for byte in &mut out[s..e.min(src.len())] {
            if *byte != b'\n' {
                *byte = b' ';
            }
        }
    }
    // every blanked span is replaced whole, so the result stays valid UTF-8
    String::from_utf8(out).expect("blanking only rewrites whole literal/comment spans")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_strings_and_comments() {
        let src = "let x = \"a.unwrap()\"; // .unwrap()\nlet y = 1;";
        let code = blank(src, false);
        assert!(!code.contains("unwrap"));
        assert!(code.contains("let y = 1;"));
        assert_eq!(code.len(), src.len());
    }

    #[test]
    fn raw_strings_with_fences() {
        let src = r####"let p = r#"panic!("no")"#; p"####;
        let code = blank(src, false);
        assert!(!code.contains("panic"));
        assert!(code.ends_with("; p"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ code()";
        let code = blank(src, false);
        assert!(!code.contains("inner"));
        assert!(code.contains("code()"));
    }

    #[test]
    fn char_vs_lifetime() {
        let src = "fn f<'a>(x: &'a str) { let c = '\"'; let d = 'é'; }";
        let code = blank(src, false);
        assert!(code.contains("fn f<'a>(x: &'a str)"));
        assert!(!code.contains('"'));
        assert!(!code.contains('é'));
    }

    #[test]
    fn keep_strings_view_drops_only_comments() {
        let src = "m.count(\"served\", 1); // bump \"fake\"";
        let v = blank(src, true);
        assert!(v.contains("\"served\""));
        assert!(!v.contains("fake"));
    }

    #[test]
    fn byte_strings_and_raw_idents() {
        let src = "let b = b\"panic!\"; let r#fn = 1;";
        let code = blank(src, false);
        assert!(!code.contains("panic"));
        assert!(code.contains("r#fn"));
    }
}
