//! Brace-scope helpers over the blanked code view: locating
//! `#[cfg(test)]` / `#[test]` item spans, matching delimiters, and mapping
//! byte offsets back to 1-based line numbers.

/// Byte spans of test-gated items: each `#[cfg(test)]`, `#[cfg(all(test`,
/// or `#[test]` attribute plus the brace-matched item that follows it
/// (or up to the `;` for item declarations like `#[cfg(test)] use ...;`).
pub fn test_spans(code: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for needle in ["#[cfg(test)]", "#[cfg(all(test", "#[test]"] {
        let mut from = 0;
        while let Some(p) = find_from(code, needle, from) {
            from = p + needle.len();
            let b = code.as_bytes();
            let mut j = p + needle.len();
            while j < b.len() {
                match b[j] {
                    b'{' => {
                        let end = close_delim(code, j, b'{', b'}');
                        spans.push((p, end));
                        break;
                    }
                    b';' => {
                        spans.push((p, j));
                        break;
                    }
                    _ => j += 1,
                }
            }
        }
    }
    spans
}

pub fn in_spans(pos: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(s, e)| s <= pos && pos < e)
}

/// 1-based line number of byte offset `pos`.
pub fn line_of(src: &str, pos: usize) -> usize {
    src.as_bytes()[..pos.min(src.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Byte offset one past the delimiter closing the one at `open_pos`.
/// Call on the blanked code view only (no delimiters inside literals).
pub fn close_delim(code: &str, open_pos: usize, open: u8, close: u8) -> usize {
    let b = code.as_bytes();
    let mut depth = 1usize;
    let mut j = open_pos + 1;
    while j < b.len() && depth > 0 {
        if b[j] == open {
            depth += 1;
        } else if b[j] == close {
            depth -= 1;
        }
        j += 1;
    }
    j
}

/// First occurrence of `needle` in `hay[from..]`, as an absolute offset.
pub fn find_from(hay: &str, needle: &str, from: usize) -> Option<usize> {
    if from >= hay.len() {
        return None;
    }
    hay[from..].find(needle).map(|p| p + from)
}

/// Is this byte part of an identifier? Multi-byte UTF-8 continuation and
/// start bytes count as identifier bytes so `née.unwrap…`-style identifiers
/// never produce false word boundaries.
pub fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric() || b >= 0x80
}

/// Occurrences of `word` in `code` bounded by non-identifier bytes.
pub fn find_word(code: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(p) = find_from(code, word, from) {
        from = p + 1;
        let before_ok = p == 0 || !is_ident_byte(b[p - 1]);
        let after = p + word.len();
        let after_ok = after >= b.len() || !is_ident_byte(b[after]);
        if before_ok && after_ok {
            out.push(p);
        }
    }
    out
}

/// Offset of the first non-whitespace byte at or after `from`.
pub fn skip_ws(code: &str, from: usize) -> usize {
    let b = code.as_bytes();
    let mut j = from;
    while j < b.len() && b[j].is_ascii_whitespace() {
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::blank;

    #[test]
    fn test_mod_span_covers_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn helper() { x.unwrap(); }\n}\n";
        let code = blank(src, false);
        let spans = test_spans(&code);
        assert_eq!(spans.len(), 1);
        let unwrap_at = code.find(".unwrap").unwrap();
        assert!(in_spans(unwrap_at, &spans));
        assert!(!in_spans(0, &spans));
    }

    #[test]
    fn word_boundaries_respect_idents() {
        let code = "a.unwrap(); a.unwrap_or(1); reunwrap();";
        assert_eq!(find_word(code, "unwrap").len(), 1);
    }

    #[test]
    fn lines_are_one_based() {
        let src = "a\nb\nc";
        assert_eq!(line_of(src, 0), 1);
        assert_eq!(line_of(src, 2), 2);
        assert_eq!(line_of(src, 4), 3);
    }
}
