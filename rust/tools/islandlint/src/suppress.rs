//! Suppression comments.
//!
//! A finding is suppressed by
//! `// islandlint: allow(<rule>) -- <reason>` either on the finding's own
//! line or anywhere in the contiguous `//` comment block immediately above
//! it. The reason is mandatory: a reasonless `allow(...)` never suppresses
//! anything and is itself reported (rule `bad-suppression`), so `--deny`
//! cannot pass on silent waivers.

use crate::Finding;

const TAG: &str = "islandlint: allow(";

/// Does line `line` (1-based) of the raw source carry or inherit a
/// well-formed suppression for `rule`?
pub fn suppressed(lines: &[&str], line: usize, rule: &str) -> bool {
    if line == 0 || line > lines.len() {
        return false;
    }
    if line_allows(lines[line - 1], rule) {
        return true;
    }
    // walk the contiguous comment block immediately above
    let mut i = line as isize - 2;
    while i >= 0 && lines[i as usize].trim_start().starts_with("//") {
        if line_allows(lines[i as usize], rule) {
            return true;
        }
        i -= 1;
    }
    false
}

fn line_allows(line: &str, rule: &str) -> bool {
    match parse_allow(line) {
        Some((r, reason)) => r == rule && !reason.is_empty(),
        None => false,
    }
}

/// `Some((rule, reason))` if the line contains an allow tag at all — the
/// reason is empty when missing, which callers treat as malformed.
fn parse_allow(line: &str) -> Option<(&str, &str)> {
    let at = line.find(TAG)?;
    let rest = &line[at + TAG.len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim();
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix("--").map(str::trim).unwrap_or("");
    Some((rule, reason))
}

/// Report every suppression in the file that names an unknown rule or
/// carries no written reason. Runs over all files regardless of directory:
/// a broken waiver is a lie wherever it sits.
pub fn malformed(rel: &str, lines: &[&str], known_rules: &[&str]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some((rule, reason)) = parse_allow(line) else { continue };
        if !known_rules.contains(&rule) {
            out.push(Finding {
                rule: "bad-suppression",
                file: rel.to_string(),
                line: idx + 1,
                message: format!("allow({rule}) names an unknown rule"),
            });
        } else if reason.is_empty() {
            out.push(Finding {
                rule: "bad-suppression",
                file: rel.to_string(),
                line: idx + 1,
                message: format!("allow({rule}) has no written reason (`-- why`)"),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_line_and_block_above() {
        let lines = [
            "// islandlint: allow(serving-path-panic) -- boot-time only",
            "// second comment line",
            "x.unwrap();",
            "y.unwrap(); // islandlint: allow(serving-path-panic) -- test fixture",
            "z.unwrap();",
        ];
        assert!(suppressed(&lines, 3, "serving-path-panic"));
        assert!(suppressed(&lines, 4, "serving-path-panic"));
        assert!(!suppressed(&lines, 5, "serving-path-panic"));
        assert!(!suppressed(&lines, 3, "lock-across-blocking"));
    }

    #[test]
    fn reasonless_allow_does_not_suppress() {
        let lines = ["// islandlint: allow(serving-path-panic)", "x.unwrap();"];
        assert!(!suppressed(&lines, 2, "serving-path-panic"));
        let bad = malformed("f.rs", &lines, &["serving-path-panic"]);
        assert_eq!(bad.len(), 1);
        assert!(bad[0].message.contains("no written reason"));
    }

    #[test]
    fn unknown_rule_is_flagged() {
        let lines = ["// islandlint: allow(made-up) -- because"];
        let bad = malformed("f.rs", &lines, &["serving-path-panic"]);
        assert_eq!(bad.len(), 1);
        assert!(bad[0].message.contains("unknown rule"));
    }
}
