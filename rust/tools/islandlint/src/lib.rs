//! islandlint: project-invariant static analysis for the IslandRun tree.
//!
//! A dependency-free lint pass over `rust/src/**`: a hand-rolled lexer
//! (raw strings, nested comments, char-boundary-correct spans), a brace
//! scope tracker, and six named rules enforcing invariants the compiler
//! cannot see — see [`rules`] for the catalogue and the README's
//! "Static analysis & sanitizers" section for suppression etiquette.
//!
//! The library surface exists so the integration tests can run individual
//! rules over fixture trees; the `islandlint` binary wraps [`run`] with
//! `--deny` / `--json` / `--rule` handling.

pub mod lexer;
pub mod rules;
pub mod scopes;
pub mod suppress;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// A source file with the derived views every rule shares.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    /// Raw text (suppression comments are read from here).
    pub src: String,
    /// Strings and comments blanked.
    pub code: String,
    /// Comments blanked, string/char literals kept (metric-name rule).
    pub nostr: String,
    /// Byte spans of `#[cfg(test)]` / `#[test]` items, over `code`.
    pub test_spans: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn parse(rel: String, src: String) -> SourceFile {
        let code = lexer::blank(&src, false);
        let nostr = lexer::blank(&src, true);
        let test_spans = scopes::test_spans(&code);
        SourceFile { rel, src, code, nostr, test_spans }
    }
}

/// The loaded tree: the `src` files under the scan root, plus the sibling
/// integration-test files (`<root>/../tests/*.rs`), which the
/// resolution-coverage rule counts as test assertions.
pub struct Tree {
    pub files: Vec<SourceFile>,
    pub test_files: Vec<SourceFile>,
}

/// Load every `.rs` file under `root`, plus the sibling `tests/` dir.
pub fn load_tree(root: &Path) -> io::Result<Tree> {
    let mut files = Vec::new();
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        files.push(SourceFile::parse(rel, fs::read_to_string(&path)?));
    }
    let mut test_files = Vec::new();
    if let Some(tests_dir) = root.parent().map(|p| p.join("tests")) {
        if tests_dir.is_dir() {
            let mut tpaths = Vec::new();
            collect_rs(&tests_dir, &mut tpaths)?;
            tpaths.sort();
            for path in tpaths {
                let rel = format!("tests/{}", path.file_name().unwrap_or_default().to_string_lossy());
                test_files.push(SourceFile::parse(rel, fs::read_to_string(&path)?));
            }
        }
    }
    Ok(Tree { files, test_files })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the selected rules (all six when `only` is empty) plus the
/// malformed-suppression sweep, sorted by file/line.
pub fn run(tree: &Tree, only: &[String]) -> Vec<Finding> {
    let enabled = |name: &str| only.is_empty() || only.iter().any(|r| r == name);
    let mut findings = Vec::new();
    if enabled("serving-path-panic") {
        findings.extend(rules::r1(tree));
    }
    if enabled("lock-across-blocking") {
        findings.extend(rules::r2(tree));
    }
    if enabled("metric-registration") {
        findings.extend(rules::r3(tree));
    }
    if enabled("resolution-coverage") {
        findings.extend(rules::r4(tree));
    }
    if enabled("trust-boundary-text") {
        findings.extend(rules::r5(tree));
    }
    if enabled("span-discipline") {
        findings.extend(rules::r6(tree));
    }
    for f in &tree.files {
        let lines: Vec<&str> = f.src.split('\n').collect();
        findings.extend(suppress::malformed(&f.rel, &lines, &rules::RULES));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

/// Count of well-formed suppression comments in the tree (reported so a
/// growing waiver list is visible in CI logs).
pub fn suppression_count(tree: &Tree) -> usize {
    tree.files
        .iter()
        .flat_map(|f| f.src.lines())
        .filter(|l| l.contains("islandlint: allow(") && l.contains("--"))
        .count()
}

/// Render findings as an aligned human-readable table.
pub fn render_table(findings: &[Finding]) -> String {
    if findings.is_empty() {
        return String::new();
    }
    let loc: Vec<String> = findings.iter().map(|f| format!("{}:{}", f.file, f.line)).collect();
    let rule_w = findings.iter().map(|f| f.rule.len()).max().unwrap_or(0);
    let loc_w = loc.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (f, l) in findings.iter().zip(&loc) {
        out.push_str(&format!("{:<rule_w$}  {:<loc_w$}  {}\n", f.rule, l, f.message));
    }
    out
}

/// Render findings as a JSON document (hand-rolled: the linter is
/// dependency-free by design).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"rule\":{},\"file\":{},\"line\":{},\"message\":{}}}",
            json_str(f.rule),
            json_str(&f.file),
            f.line,
            json_str(&f.message)
        ));
    }
    out.push_str(&format!("],\"total\":{}}}", findings.len()));
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        let f = vec![Finding {
            rule: "serving-path-panic",
            file: "a\\b.rs".to_string(),
            line: 3,
            message: "uses \"quotes\"".to_string(),
        }];
        let j = render_json(&f);
        assert!(j.contains(r#""file":"a\\b.rs""#), "{j}");
        assert!(j.contains(r#"uses \"quotes\""#), "{j}");
        assert!(j.ends_with(",\"total\":1}"), "{j}");
    }

    #[test]
    fn empty_run_renders_empty() {
        assert_eq!(render_table(&[]), "");
        assert_eq!(render_json(&[]), "{\"findings\":[],\"total\":0}");
    }
}
