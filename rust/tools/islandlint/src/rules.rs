//! The six project-invariant rules.
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 `serving-path-panic`    | no panicking constructs in non-test serving code |
//! | R2 `lock-across-blocking`  | no lock guard held across a blocking call |
//! | R3 `metric-registration`   | metric-name literals must be pre-registered and exposition-safe |
//! | R4 `resolution-coverage`   | every Resolution-family variant has a terminal site and a test |
//! | R5 `trust-boundary-text`   | island-bound text is dispatched only by sanitize-owning modules |
//! | R6 `span-discipline`       | every audited Resolution terminal also ends the request span |
//!
//! Every rule works on the blanked code view (strings and comments cannot
//! produce findings), skips `#[cfg(test)]` spans where the invariant is
//! test-only noise, and honors `// islandlint: allow(rule) -- reason`
//! suppressions.

use crate::scopes::{close_delim, find_from, find_word, in_spans, is_ident_byte, line_of, skip_ws};
use crate::suppress::suppressed;
use crate::{Finding, SourceFile, Tree};

/// Directories that make up the serving path, relative to the scan root.
pub const SERVING_DIRS: [&str; 6] =
    ["server/", "runtime/", "telemetry/", "agents/", "islands/", "substrate/"];

pub const RULES: [&str; 6] = [
    "serving-path-panic",
    "lock-across-blocking",
    "metric-registration",
    "resolution-coverage",
    "trust-boundary-text",
    "span-discipline",
];

fn serving(rel: &str) -> bool {
    SERVING_DIRS.iter().any(|d| rel.starts_with(d))
}

fn lines_of(f: &SourceFile) -> Vec<&str> {
    f.src.split('\n').collect()
}

/// Next non-whitespace byte after `pos` equals `want`?
fn next_is(code: &str, pos: usize, want: u8) -> bool {
    let j = skip_ws(code, pos);
    j < code.len() && code.as_bytes()[j] == want
}

// ---------------------------------------------------------------- R1 ----

/// Panicking constructs denied on the serving path: `.unwrap()`,
/// `.expect(...)`, `panic!`, `todo!`, `unimplemented!`. Indexing (`x[i]`)
/// is intentionally out of scope: the tree indexes fixed-shape data behind
/// validated invariants, and a byte-level heuristic cannot tell those from
/// adjacent panics without drowning the signal.
pub fn r1(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "serving-path-panic";
    let mut out = Vec::new();
    for f in &tree.files {
        if !serving(&f.rel) {
            continue;
        }
        let lines = lines_of(f);
        let mut hits: Vec<(usize, &str)> = Vec::new();
        for method in [".unwrap", ".expect"] {
            for p in method_calls(&f.code, method) {
                hits.push((p, method));
            }
        }
        for mac in ["panic", "todo", "unimplemented"] {
            for p in find_word(&f.code, mac) {
                let b = f.code.as_bytes();
                let after = p + mac.len();
                if after < b.len() && b[after] == b'!' {
                    let j = skip_ws(&f.code, after + 1);
                    if j < b.len() && (b[j] == b'(' || b[j] == b'{') {
                        hits.push((p, mac));
                    }
                }
            }
        }
        hits.sort_unstable();
        for (p, what) in hits {
            if in_spans(p, &f.test_spans) {
                continue;
            }
            let line = line_of(&f.src, p);
            if suppressed(&lines, line, RULE) {
                continue;
            }
            out.push(Finding {
                rule: RULE,
                file: f.rel.clone(),
                line,
                message: format!("`{what}` can panic on the serving path; return a typed error instead"),
            });
        }
    }
    out
}

/// Occurrences of `.name` followed (modulo whitespace) by `(`, where `name`
/// is a whole identifier (`.unwrap_or(` does not match `.unwrap`).
fn method_calls(code: &str, dot_name: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = find_from(code, dot_name, from) {
        from = p + 1;
        let after = p + dot_name.len();
        if after < b.len() && is_ident_byte(b[after]) {
            continue;
        }
        if next_is(code, after, b'(') {
            out.push(p);
        }
    }
    out
}

// ---------------------------------------------------------------- R2 ----

/// Blocking calls a guard must not be held across. `(needle,
/// requires_empty_args)`: `.join()` only blocks with no arguments
/// (`v.join(", ")` is string joining), same for `.recv()` / `.accept()`.
const BLOCKING_METHODS: [(&str, bool); 9] = [
    (".wait", false),
    (".wait_timeout", false),
    (".wait_while", false),
    (".recv_timeout", false),
    (".read_exact", false),
    (".write_all", false),
    (".recv", true),
    (".join", true),
    (".accept", true),
];
const BLOCKING_FNS: [&str; 4] = ["cond_wait", "cond_wait_while", "cond_wait_timeout", "sleep"];

/// Initializer suffixes that produce a lock guard (whitespace-normalized).
const GUARD_SUFFIXES: [&str; 9] = [
    ".lock_clean()",
    ".read_clean()",
    ".write_clean()",
    ".lock().unwrap()",
    ".read().unwrap()",
    ".write().unwrap()",
    ".lock()?",
    ".read()?",
    ".write()?",
];

/// A `let guard = ….lock…()` binding whose scope contains a blocking call
/// before the guard drops. The guard being *passed to* the blocking call is
/// the condvar handoff idiom and is exempt; so is anything after an
/// explicit `drop(guard)`.
pub fn r2(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "lock-across-blocking";
    let mut out = Vec::new();
    for f in &tree.files {
        if !serving(&f.rel) {
            continue;
        }
        let lines = lines_of(f);
        for p in find_word(&f.code, "let") {
            if in_spans(p, &f.test_spans) {
                continue;
            }
            let Some((name, stmt_end)) = parse_guard_binding(&f.code, p) else { continue };
            // scope: from the end of the statement to the close of the
            // enclosing block
            let b = f.code.as_bytes();
            let mut depth = 0i32;
            let mut j = stmt_end;
            let mut scope_end = b.len();
            while j < b.len() {
                match b[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth < 0 {
                            scope_end = j;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            let mut scope = &f.code[stmt_end..scope_end];
            let scope_base = stmt_end;
            if let Some(d) = find_drop(scope, &name) {
                scope = &scope[..d];
            }
            if let Some((at, what)) = first_blocking(scope, &name) {
                let line = line_of(&f.src, scope_base + at);
                let guard_line = line_of(&f.src, p);
                if suppressed(&lines, line, RULE) || suppressed(&lines, guard_line, RULE) {
                    continue;
                }
                out.push(Finding {
                    rule: RULE,
                    file: f.rel.clone(),
                    line,
                    message: format!(
                        "guard `{name}` (bound on line {guard_line}) is held across blocking `{what}`"
                    ),
                });
            }
        }
    }
    out
}

/// If the `let` at `let_pos` binds a lock guard, return (name, end-of-stmt).
fn parse_guard_binding(code: &str, let_pos: usize) -> Option<(String, usize)> {
    let b = code.as_bytes();
    let mut j = skip_ws(code, let_pos + 3);
    // optional `mut`
    if code[j..].starts_with("mut") && j + 3 < b.len() && !is_ident_byte(b[j + 3]) {
        j = skip_ws(code, j + 3);
    }
    // simple identifier pattern only (destructuring never binds a bare guard)
    let start = j;
    while j < b.len() && is_ident_byte(b[j]) {
        j += 1;
    }
    if j == start {
        return None;
    }
    let name = code[start..j].to_string();
    j = skip_ws(code, j);
    if j >= b.len() {
        return None;
    }
    // optional `: Type` annotation up to `=`
    if b[j] == b':' {
        while j < b.len() && b[j] != b'=' && b[j] != b';' && b[j] != b'{' {
            j += 1;
        }
    }
    if j >= b.len() || b[j] != b'=' || (j + 1 < b.len() && b[j + 1] == b'=') {
        return None;
    }
    // initializer runs to `;`; bail on `{` (closures/blocks — not a simple
    // guard acquisition)
    let init_start = j + 1;
    let mut k = init_start;
    while k < b.len() {
        match b[k] {
            b';' => break,
            b'{' => return None,
            _ => k += 1,
        }
    }
    if k >= b.len() {
        return None;
    }
    let normalized: String =
        code[init_start..k].chars().filter(|c| !c.is_whitespace()).collect();
    if GUARD_SUFFIXES.iter().any(|s| normalized.ends_with(s)) {
        Some((name, k + 1))
    } else {
        None
    }
}

fn find_drop(scope: &str, name: &str) -> Option<usize> {
    for p in find_word(scope, "drop") {
        let open = skip_ws(scope, p + 4);
        if open < scope.len() && scope.as_bytes()[open] == b'(' {
            let inner = skip_ws(scope, open + 1);
            let boundary_ok =
                scope.as_bytes().get(inner + name.len()).map_or(true, |&c| !is_ident_byte(c));
            if scope[inner..].starts_with(name) && boundary_ok {
                return Some(p);
            }
        }
    }
    None
}

/// First blocking call in `scope` that does not receive `name` as an
/// argument, as (offset, matched call).
fn first_blocking(scope: &str, name: &str) -> Option<(usize, String)> {
    let mut best: Option<(usize, String)> = None;
    let b = scope.as_bytes();
    let mut consider = |p: usize, what: &str, open: usize| {
        let close = close_delim(scope, open, b'(', b')');
        let args = &scope[open + 1..close.saturating_sub(1).max(open + 1)];
        if find_word(args, name).is_empty() {
            if best.as_ref().map(|(bp, _)| p < *bp).unwrap_or(true) {
                best = Some((p, what.to_string()));
            }
        }
    };
    for (needle, empty_only) in BLOCKING_METHODS {
        for p in method_calls(scope, needle) {
            let open = skip_ws(scope, p + needle.len());
            if empty_only {
                let inner = skip_ws(scope, open + 1);
                if inner >= b.len() || b[inner] != b')' {
                    continue;
                }
            }
            consider(p, needle, open);
        }
    }
    for fnname in BLOCKING_FNS {
        for p in find_word(scope, fnname) {
            // function position: not a method call on some receiver
            if p > 0 && b[p - 1] == b'.' {
                continue;
            }
            let after = p + fnname.len();
            if !next_is(scope, after, b'(') {
                continue;
            }
            let open = skip_ws(scope, after);
            consider(p, fnname, open);
        }
    }
    best
}

// ---------------------------------------------------------------- R3 ----

const REGISTER_FNS: [&str; 6] = [
    "register_counter",
    "counter_vec",
    "register_gauge",
    "gauge_vec",
    "register_histogram",
    "histogram_vec",
];
const BUMP_FNS: [&str; 8] = [
    ".count",
    ".gauge",
    ".observe",
    ".counter_value",
    ".gauge_value",
    ".histogram",
    ".counter_children",
    ".histogram_children",
];
const RESERVED_SUFFIXES: [&str; 4] = ["_total", "_bucket", "_sum", "_count"];

/// Metric-name literals must be pre-registered (or be a declared
/// `HTTP_ROUTES` route, for the HTTP per-route observe path), and
/// registered names must survive the Prometheus renderer: valid charset,
/// no reserved `_total`/`_bucket`/`_sum`/`_count` suffix that would collide
/// with generated sample names (`telemetry::lint_exposition` enforces the
/// same rule on the rendered text).
pub fn r3(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "metric-registration";
    let mut out = Vec::new();
    let mut registered: Vec<String> = Vec::new();
    for f in &tree.files {
        for fnname in REGISTER_FNS {
            for p in find_word(&f.nostr, fnname) {
                if let Some(name) = first_literal_arg(&f.nostr, p + fnname.len()) {
                    registered.push(name);
                }
            }
        }
        // HTTP_ROUTES route names count as registered label values for the
        // per-route HTTP observe path
        for p in find_word(&f.nostr, "HTTP_ROUTES") {
            if let Some(open) = find_from(&f.nostr, "[", p) {
                if let Some(open) = find_from(&f.nostr, "[", open + 1) {
                    let close = close_delim(&f.nostr, open, b'[', b']');
                    registered.extend(literals_in(&f.nostr[open..close]));
                }
            }
        }
    }
    for f in &tree.files {
        let lines = lines_of(f);
        for fnname in REGISTER_FNS {
            for p in find_word(&f.nostr, fnname) {
                let Some(name) = first_literal_arg(&f.nostr, p + fnname.len()) else { continue };
                let line = line_of(&f.src, p);
                if !valid_metric_name(&name) && !suppressed(&lines, line, RULE) {
                    out.push(Finding {
                        rule: RULE,
                        file: f.rel.clone(),
                        line,
                        message: format!("metric name {name:?} violates prometheus naming rules"),
                    });
                }
                if let Some(suf) = RESERVED_SUFFIXES.iter().find(|s| name.ends_with(**s)) {
                    if !suppressed(&lines, line, RULE) {
                        out.push(Finding {
                            rule: RULE,
                            file: f.rel.clone(),
                            line,
                            message: format!(
                                "metric name {name:?} ends in reserved suffix `{suf}` and would collide with generated exposition samples"
                            ),
                        });
                    }
                }
            }
        }
        if !serving(&f.rel) {
            continue;
        }
        for fnname in BUMP_FNS {
            for p in find_word(&f.nostr, &fnname[1..]) {
                if p == 0 || f.nostr.as_bytes()[p - 1] != b'.' {
                    continue;
                }
                if in_spans(p, &f.test_spans) {
                    continue;
                }
                let Some(name) = first_literal_arg(&f.nostr, p + fnname.len() - 1) else { continue };
                if registered.iter().any(|r| r == &name) {
                    continue;
                }
                let line = line_of(&f.src, p);
                if suppressed(&lines, line, RULE) {
                    continue;
                }
                out.push(Finding {
                    rule: RULE,
                    file: f.rel.clone(),
                    line,
                    message: format!(
                        "metric {name:?} bumped or read via `{fnname}` without a pre-registered handle"
                    ),
                });
            }
        }
    }
    out
}

/// If the call at `after_name` opens with a string literal, return it.
fn first_literal_arg(nostr: &str, after_name: usize) -> Option<String> {
    let b = nostr.as_bytes();
    let open = skip_ws(nostr, after_name);
    if open >= b.len() || b[open] != b'(' {
        return None;
    }
    let q = skip_ws(nostr, open + 1);
    if q >= b.len() || b[q] != b'"' {
        return None;
    }
    let mut j = q + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return Some(nostr[q + 1..j].to_string()),
            _ => j += 1,
        }
    }
    None
}

fn literals_in(nostr: &str) -> Vec<String> {
    let b = nostr.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'"' {
            let mut j = i + 1;
            while j < b.len() && b[j] != b'"' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            if j < b.len() {
                out.push(nostr[i + 1..j].to_string());
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

// ---------------------------------------------------------------- R4 ----

const RESOLUTION_ENUMS: [&str; 4] = ["ShedReason", "CancelPoint", "FailReason", "Resolution"];

/// Every variant of the Resolution enum family must appear at a terminal
/// site (non-test `server/` code) and in at least one test assertion (a
/// `#[cfg(test)]` span or the integration-test tree).
pub fn r4(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "resolution-coverage";
    let mut out = Vec::new();
    let Some(res) = tree.files.iter().find(|f| f.rel == "server/resolution.rs") else {
        return out; // nothing to check in trees without the enum family
    };
    let res_lines = lines_of(res);
    let mut variants: Vec<(&str, String, usize)> = Vec::new();
    for e in RESOLUTION_ENUMS {
        for (v, off) in enum_variants(&res.code, e) {
            variants.push((e, v, off));
        }
    }
    for (enum_name, variant, def_off) in variants {
        let mut terminal = 0usize;
        let mut tested = 0usize;
        for f in &tree.files {
            if f.rel == "server/resolution.rs" {
                continue;
            }
            for p in find_word(&f.code, &variant) {
                if in_spans(p, &f.test_spans) {
                    tested += 1;
                } else if f.rel.starts_with("server/") {
                    terminal += 1;
                }
            }
        }
        for f in &tree.test_files {
            tested += find_word(&f.code, &variant).len();
        }
        let line = line_of(&res.src, def_off);
        if suppressed(&res_lines, line, RULE) {
            continue;
        }
        if terminal == 0 {
            out.push(Finding {
                rule: RULE,
                file: res.rel.clone(),
                line,
                message: format!("{enum_name}::{variant} has no terminal site in non-test server/ code"),
            });
        }
        if tested == 0 {
            out.push(Finding {
                rule: RULE,
                file: res.rel.clone(),
                line,
                message: format!("{enum_name}::{variant} is never named in a test assertion"),
            });
        }
    }
    out
}

/// `(variant, byte offset)` list for `enum name { ... }` in the code view.
fn enum_variants(code: &str, name: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for p in find_word(code, "enum") {
        let after = skip_ws(code, p + 4);
        if !code[after..].starts_with(name) {
            continue;
        }
        let post = after + name.len();
        if post < code.len() && is_ident_byte(code.as_bytes()[post]) {
            continue;
        }
        let Some(open) = find_from(code, "{", post) else { continue };
        let close = close_delim(code, open, b'{', b'}');
        let body = &code[open + 1..close.saturating_sub(1)];
        // split on depth-0 commas, take the first identifier of each chunk
        let mut depth = 0i32;
        let mut chunk_start = 0usize;
        let bytes = body.as_bytes();
        let mut chunks = Vec::new();
        for (i, &b) in bytes.iter().enumerate() {
            match b {
                b'(' | b'{' | b'[' | b'<' => depth += 1,
                b')' | b'}' | b']' | b'>' => depth -= 1,
                b',' if depth == 0 => {
                    chunks.push((chunk_start, i));
                    chunk_start = i + 1;
                }
                _ => {}
            }
        }
        chunks.push((chunk_start, body.len()));
        for (s, e) in chunks {
            let chunk = &body[s..e];
            let cb = chunk.as_bytes();
            let mut i = 0;
            while i < cb.len() && !is_ident_byte(cb[i]) {
                i += 1;
            }
            let start = i;
            while i < cb.len() && is_ident_byte(cb[i]) {
                i += 1;
            }
            if start < i && chunk[start..].chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                out.push((chunk[start..i].to_string(), open + 1 + s + start));
            }
        }
        break;
    }
    out
}

// ---------------------------------------------------------------- R5 ----

/// Modules allowed to construct and dispatch island-bound text: the
/// orchestrator (which owns `sanitize_for_target`) and the island layer it
/// hands sanitized requests to.
pub const TRUST_ALLOWED: [&str; 2] = ["server/orchestrator.rs", "islands/"];
const DISPATCH_METHODS: [&str; 4] = [".prefill", ".execute_batch", ".execute", ".generate"];

/// Island-bound request/prefill dispatch outside the sanitize-owning
/// modules. Any new call path that hands text to an island must route
/// through the orchestrator's sanitize chokepoint first.
pub fn r5(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "trust-boundary-text";
    let mut out = Vec::new();
    for f in &tree.files {
        if !serving(&f.rel) || TRUST_ALLOWED.iter().any(|a| f.rel.starts_with(a)) {
            continue;
        }
        let lines = lines_of(f);
        let mut hits: Vec<(usize, String)> = Vec::new();
        for m in DISPATCH_METHODS {
            for p in method_calls(&f.code, m) {
                hits.push((p, format!("{m}(...)")));
            }
        }
        for p in find_word(&f.code, "sanitize_for_target") {
            hits.push((p, "sanitize_for_target".to_string()));
        }
        hits.sort_unstable_by_key(|(p, _)| *p);
        for (p, what) in hits {
            if in_spans(p, &f.test_spans) {
                continue;
            }
            let line = line_of(&f.src, p);
            if suppressed(&lines, line, RULE) {
                continue;
            }
            out.push(Finding {
                rule: RULE,
                file: f.rel.clone(),
                line,
                message: format!(
                    "island-bound dispatch `{what}` outside sanitize-owning modules ({})",
                    TRUST_ALLOWED.join(", ")
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------- R6 ----

/// A terminal site — a non-test `server/` fn that constructs a
/// `Resolution::…` value and records an audit entry (`.record(…)`) — must
/// also close the request's trace via `.end_request_span(…)`. A terminal
/// that audits but leaves the span open strands the trace: it never
/// reaches the sink's ring, the exporters, or `GET /v1/traces/:id`, and the
/// event/audit rows' `trace_id` silently stays null. The inert-context
/// no-op makes the call free on untraced requests, so there is no
/// performance excuse for skipping it.
pub fn r6(tree: &Tree) -> Vec<Finding> {
    const RULE: &str = "span-discipline";
    let mut out = Vec::new();
    for f in &tree.files {
        if !f.rel.starts_with("server/") {
            continue;
        }
        let lines = lines_of(f);
        for (fn_pos, body_start, body_end) in fn_bodies(&f.code) {
            if in_spans(fn_pos, &f.test_spans) {
                continue;
            }
            let body = &f.code[body_start..body_end];
            if !constructs_resolution(body) || method_calls(body, ".record").is_empty() {
                continue;
            }
            if !method_calls(body, ".end_request_span").is_empty() {
                continue;
            }
            let line = line_of(&f.src, fn_pos);
            if suppressed(&lines, line, RULE) {
                continue;
            }
            out.push(Finding {
                rule: RULE,
                file: f.rel.clone(),
                line,
                message: "terminal site audits a Resolution but never calls `.end_request_span(...)`; \
                          the request's trace is stranded open"
                    .to_string(),
            });
        }
    }
    out
}

/// `Resolution::Variant` construction (the path form; a bare `Resolution`
/// type mention — parameters, matches on a borrowed value — is not a
/// terminal).
fn constructs_resolution(code: &str) -> bool {
    find_word(code, "Resolution").iter().any(|&p| code[p + "Resolution".len()..].starts_with("::"))
}

/// `(fn_offset, body_start, body_end)` for every `fn` with a block body,
/// in the blanked code view. Bodyless declarations (trait methods, extern
/// fns — a `;` before the `{`) are skipped so a neighbour's body is never
/// mis-attributed.
fn fn_bodies(code: &str) -> Vec<(usize, usize, usize)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for p in find_word(code, "fn") {
        let mut j = p + 2;
        let mut open = None;
        while j < b.len() {
            match b[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let Some(open) = open else { continue };
        let close = close_delim(code, open, b'{', b'}');
        out.push((p, open + 1, close.saturating_sub(1).max(open + 1)));
    }
    out
}
