//! islandlint CLI.
//!
//! ```text
//! islandlint [ROOT] [--deny] [--json] [--rule NAME]...
//! ```
//!
//! ROOT defaults to the workspace's `rust/src` (resolved relative to the
//! current directory, then to the crate's own manifest, so both
//! `cargo run -p islandlint` from the workspace root and the installed
//! binary find the tree). Exit status: 0 when clean or when findings exist
//! without `--deny`; 2 on findings under `--deny`; 1 on usage/IO errors.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut deny = false;
    let mut json = false;
    let mut only: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--deny" => deny = true,
            "--json" => json = true,
            "--rule" => match args.next() {
                Some(r) if islandlint::rules::RULES.contains(&r.as_str()) => only.push(r),
                Some(r) => {
                    eprintln!(
                        "islandlint: unknown rule {r:?} (known: {})",
                        islandlint::rules::RULES.join(", ")
                    );
                    return ExitCode::from(1);
                }
                None => {
                    eprintln!("islandlint: --rule needs a rule name");
                    return ExitCode::from(1);
                }
            },
            "--help" | "-h" => {
                println!("usage: islandlint [ROOT] [--deny] [--json] [--rule NAME]...");
                println!("rules: {}", islandlint::rules::RULES.join(", "));
                return ExitCode::SUCCESS;
            }
            _ if root.is_none() && !arg.starts_with('-') => root = Some(PathBuf::from(arg)),
            _ => {
                eprintln!("islandlint: unexpected argument {arg:?}");
                return ExitCode::from(1);
            }
        }
    }

    let root = match root.or_else(default_root) {
        Some(r) => r,
        None => {
            eprintln!("islandlint: could not locate rust/src; pass the tree root explicitly");
            return ExitCode::from(1);
        }
    };
    let tree = match islandlint::load_tree(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("islandlint: failed to read {}: {e}", root.display());
            return ExitCode::from(1);
        }
    };

    let findings = islandlint::run(&tree, &only);
    if json {
        println!("{}", islandlint::render_json(&findings));
    } else if findings.is_empty() {
        println!(
            "islandlint: clean — {} files, {} suppressions with written reasons",
            tree.files.len(),
            islandlint::suppression_count(&tree)
        );
    } else {
        print!("{}", islandlint::render_table(&findings));
        println!("islandlint: {} finding(s)", findings.len());
    }
    if deny && !findings.is_empty() {
        return ExitCode::from(2);
    }
    ExitCode::SUCCESS
}

/// `rust/src` relative to the current directory, the crate manifest
/// (`tools/islandlint` → workspace `rust/src`), or `src` when run from
/// inside `rust/`.
fn default_root() -> Option<PathBuf> {
    let candidates = [
        PathBuf::from("rust/src"),
        PathBuf::from("src"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src"),
    ];
    candidates.into_iter().find(|p| p.is_dir())
}
