//! Self-check: the real `rust/src` tree is clean under `--deny` semantics.
//!
//! This is the test CI leans on: any new panic on the serving path, guard
//! held across a blocking call, unregistered metric literal, uncovered
//! resolution variant, rogue island dispatch, or reasonless suppression in
//! the main crate fails this test before the lint job even runs.

use std::path::Path;

#[test]
fn real_tree_is_clean_under_deny() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let tree = islandlint::load_tree(&root).expect("rust/src must load");
    assert!(tree.files.len() > 30, "expected the full source tree, found {}", tree.files.len());
    assert!(!tree.test_files.is_empty(), "rust/tests must be visible for resolution-coverage");

    let findings = islandlint::run(&tree, &[]);
    assert!(
        findings.is_empty(),
        "islandlint found violations in rust/src:\n{}",
        islandlint::render_table(&findings)
    );

    // The waivers that do exist all carry written reasons (a reasonless one
    // would have surfaced above as bad-suppression).
    assert!(
        islandlint::suppression_count(&tree) >= 1,
        "the tree documents its boot-time panic waivers"
    );
}
