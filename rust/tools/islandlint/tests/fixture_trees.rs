//! Rule-engine acceptance over the fixture corpora: every rule fires on
//! its seeded violations and stays quiet on the clean tree.

use std::path::Path;

fn tree(which: &str) -> islandlint::Tree {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(which);
    islandlint::load_tree(&root).expect("fixture tree loads")
}

fn count(findings: &[islandlint::Finding], rule: &str) -> usize {
    findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn clean_tree_is_clean() {
    let findings = islandlint::run(&tree("clean"), &[]);
    assert!(
        findings.is_empty(),
        "clean fixture tree must produce no findings:\n{}",
        islandlint::render_table(&findings)
    );
}

#[test]
fn violating_tree_fires_every_rule() {
    let findings = islandlint::run(&tree("violating"), &[]);

    // R1: unwrap/expect/panic!/todo!/unimplemented! in panics.rs, plus the
    // reasonless-allow unwrap in waived.rs; decoys and test code stay quiet
    assert_eq!(count(&findings, "serving-path-panic"), 6, "{}", islandlint::render_table(&findings));
    let r1_files: Vec<&str> = findings
        .iter()
        .filter(|f| f.rule == "serving-path-panic")
        .map(|f| f.file.as_str())
        .collect();
    assert!(r1_files.contains(&"server/panics.rs"));
    assert!(r1_files.contains(&"server/waived.rs"), "reasonless allow must not suppress");

    // R2: guard across recv, guard across sleep
    assert_eq!(count(&findings, "lock-across-blocking"), 2, "{}", islandlint::render_table(&findings));
    assert!(findings
        .iter()
        .any(|f| f.rule == "lock-across-blocking" && f.message.contains("`.recv`")));
    assert!(findings
        .iter()
        .any(|f| f.rule == "lock-across-blocking" && f.message.contains("`sleep`")));

    // R3: bad charset, reserved suffix, unregistered bump
    assert_eq!(count(&findings, "metric-registration"), 3, "{}", islandlint::render_table(&findings));
    assert!(findings.iter().any(|f| f.message.contains("\"bad-name\"")));
    assert!(findings.iter().any(|f| f.message.contains("reserved suffix")));
    assert!(findings.iter().any(|f| f.message.contains("\"never_registered\"")));

    // R4: Ghost has neither a terminal site nor a test assertion
    assert_eq!(count(&findings, "resolution-coverage"), 2, "{}", islandlint::render_table(&findings));
    assert!(findings
        .iter()
        .all(|f| f.rule != "resolution-coverage" || f.message.contains("ShedReason::Ghost")));

    // R5: .execute / .generate / sanitize_for_target outside allowed modules
    assert_eq!(count(&findings, "trust-boundary-text"), 3, "{}", islandlint::render_table(&findings));
    assert!(findings
        .iter()
        .all(|f| f.rule != "trust-boundary-text" || f.file == "runtime/dispatch.rs"));

    // R6: one audited terminal never ends the request span; the compliant
    // sibling and the test-only helper stay quiet
    assert_eq!(count(&findings, "span-discipline"), 1, "{}", islandlint::render_table(&findings));
    assert!(findings
        .iter()
        .all(|f| f.rule != "span-discipline" || f.file == "server/spans.rs"));

    // malformed suppressions: reasonless + unknown rule
    assert_eq!(count(&findings, "bad-suppression"), 2, "{}", islandlint::render_table(&findings));
}

#[test]
fn rule_selection_filters_findings() {
    let t = tree("violating");
    let only = vec!["serving-path-panic".to_string()];
    let findings = islandlint::run(&t, &only);
    // bad-suppression always runs; the other five rules are off
    assert!(findings.iter().all(|f| f.rule == "serving-path-panic" || f.rule == "bad-suppression"));
    assert_eq!(count(&findings, "serving-path-panic"), 6);
}

#[test]
fn suppressions_round_trip() {
    // Each violating finding disappears when the exact rule is allowed with
    // a reason on the preceding line, and survives a mismatched rule name.
    let src = "\
// islandlint: allow(serving-path-panic) -- fixture waiver
pub fn a(v: Option<u8>) -> u8 { v.unwrap() }
pub fn b(v: Option<u8>) -> u8 { v.unwrap() }
";
    let tree = islandlint::Tree {
        files: vec![islandlint::SourceFile::parse("server/x.rs".to_string(), src.to_string())],
        test_files: vec![],
    };
    let findings = islandlint::run(&tree, &[]);
    assert_eq!(findings.len(), 1, "{}", islandlint::render_table(&findings));
    assert_eq!(findings[0].line, 3, "only the unwaived line fires");
}

#[test]
fn json_output_is_stable() {
    let findings = islandlint::run(&tree("violating"), &["resolution-coverage".to_string()]);
    let json = islandlint::render_json(&findings);
    assert!(json.starts_with("{\"findings\":["));
    assert!(json.contains("\"rule\":\"resolution-coverage\""));
    assert!(json.contains("\"file\":\"server/resolution.rs\""));
}
