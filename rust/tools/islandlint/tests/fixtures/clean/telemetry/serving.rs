//! Clean telemetry: pre-registered handles, route-label observes.

pub const HTTP_ROUTES: [&str; 2] = ["submit", "other"];

pub fn register(m: &Metrics) -> Counter {
    m.register_counter("requests_served", "requests served end to end")
}

pub fn bump(m: &Metrics, h: &HttpMetrics) {
    m.count("requests_served", 1);
    h.observe("other", 200, 1.0);
}
