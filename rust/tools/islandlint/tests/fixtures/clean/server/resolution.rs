//! Clean-tree resolution enums: every variant is covered in `ok.rs`.

pub enum ShedReason {
    QueueFull,
}

pub enum Resolution {
    Served,
    Shed(ShedReason),
}
