//! Clean serving code: every rule's "stays quiet" side.
//!
//! Covers the condvar handoff exemption, drop-before-blocking, a
//! suppressed boot-time panic with a written reason, raw-string and
//! comment decoys, and terminal sites plus test assertions for every
//! resolution variant.

use crate::util::sync::{cond_wait, LockExt};

pub fn resolve(r: Resolution) -> &'static str {
    match r {
        Resolution::Served => "served",
        Resolution::Shed(ShedReason::QueueFull) => "queue_full",
    }
}

pub struct Waiter {
    state: std::sync::Mutex<u64>,
    cond: std::sync::Condvar,
}

impl Waiter {
    /// Handing the guard to the condvar is the sanctioned blocking idiom.
    pub fn bump_and_wait(&self) -> u64 {
        let guard = self.state.lock_clean();
        let guard = cond_wait(&self.cond, guard);
        *guard
    }

    /// Dropping the guard before blocking is always fine.
    pub fn peek_then_sleep(&self) -> u64 {
        let guard = self.state.lock_clean();
        let v = *guard;
        drop(guard);
        std::thread::sleep(std::time::Duration::from_millis(1));
        v
    }
}

pub fn boot_pattern() -> Regex {
    // islandlint: allow(serving-path-panic) -- fixture: constant pattern compiled once at boot, covered by unit tests
    Regex::new("^ok$").unwrap()
}

pub fn decoys() -> usize {
    let quiet = r##"q.unwrap() and unimplemented!() live in a raw string"##;
    // mentioning z.expect("nope") in a comment is fine
    quiet.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assertions_name_every_variant() {
        assert_eq!(resolve(Resolution::Served), "served");
        assert_eq!(resolve(Resolution::Shed(ShedReason::QueueFull)), "queue_full");
    }
}
