//! R6 clean side: the audited terminal closes its span, and a match
//! that names variants without recording anything is not a terminal.

pub fn close_out(audit: &Audit, trace: &TraceContext, now_ms: f64) {
    let resolution = Resolution::Shed(ShedReason::QueueFull);
    audit.record(&resolution, now_ms);
    trace.end_request_span(now_ms, resolution.class(), resolution.reason());
}

pub fn describe(r: &Resolution) -> &'static str {
    match r {
        Resolution::Served => "served",
        _ => "other",
    }
}
