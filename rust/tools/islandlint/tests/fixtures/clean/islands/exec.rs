//! The island layer is allow-listed for dispatch: it receives requests
//! already sanitized by the orchestrator chokepoint.

pub fn run(fleet: &Fleet, req: &Request) -> Response {
    fleet.execute(req.target, req)
}
