//! R5 seeds: island-bound dispatch outside the sanitize-owning modules.

pub fn rogue(fleet: &Fleet, engine: &Engine, req: &Request) {
    let _ = fleet.execute(req.target, req);
    let _ = engine.generate(vec![req.prompt.clone()], 8);
}

pub fn rewrap(orch: &Orchestrator, p: &mut Prepared) {
    let _ = orch.sanitize_for_target(p);
}
