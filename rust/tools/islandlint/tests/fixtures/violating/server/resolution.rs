//! R4 seed: `Ghost` is declared but has no terminal site and no test.

pub enum ShedReason {
    QueueFull,
    Ghost,
}

pub enum Resolution {
    Served,
    Shed(ShedReason),
}
