//! Terminal sites and test assertions for every variant except `Ghost`.

pub fn finish(r: Resolution) -> &'static str {
    match r {
        Resolution::Served => "served",
        Resolution::Shed(ShedReason::QueueFull) => "queue_full",
        Resolution::Shed(_) => "other",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(finish(Resolution::Served), "served");
        assert_eq!(finish(Resolution::Shed(ShedReason::QueueFull)), "queue_full");
    }
}
