//! R6 seed: one audited terminal leaves the request span open; the
//! compliant terminal and the test-only helper stay quiet.

pub fn shed_without_closing(audit: &Audit, trace: &TraceContext, now_ms: f64) {
    let resolution = Resolution::Shed(ShedReason::QueueFull);
    audit.record(&resolution, now_ms);
    let _ = trace; // the span is never ended: span-discipline fires here
}

pub fn shed_and_close(audit: &Audit, trace: &TraceContext, now_ms: f64) {
    let resolution = Resolution::Shed(ShedReason::QueueFull);
    audit.record(&resolution, now_ms);
    trace.end_request_span(now_ms, resolution.class(), resolution.reason());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_code_may_audit_without_a_span() {
        let audit = Audit::default();
        audit.record(&Resolution::Served, 0.0);
    }
}
