//! R2 seeds: lock guards held across blocking calls.

use std::sync::{Condvar, Mutex};

pub struct Q {
    items: Mutex<Vec<u64>>,
    cond: Condvar,
}

impl Q {
    pub fn drain_badly(&self, rx: &std::sync::mpsc::Receiver<u64>) -> Vec<u64> {
        let mut held = self.items.lock_clean();
        let next = rx.recv();
        if let Ok(v) = next {
            held.push(v);
        }
        held.clone()
    }

    pub fn sleepy(&self) -> usize {
        let held = self.items.lock_clean();
        std::thread::sleep(std::time::Duration::from_millis(5));
        held.len()
    }
}
