//! Malformed suppressions: a reasonless allow (which also fails to
//! suppress the finding beneath it) and an allow naming an unknown rule.

pub fn waived() -> String {
    // islandlint: allow(serving-path-panic)
    let home = std::env::var("HOME").unwrap();
    // islandlint: allow(made-up-rule) -- this rule does not exist
    home
}
