//! R1 seeds: every panicking construct fires once, decoys stay quiet.
//! UTF-8 identifiers exercise the lexer's char-boundary handling.

pub fn décode(café: Option<u32>) -> u32 {
    café.unwrap()
}

pub fn strict(v: Result<u8, String>) -> u8 {
    v.expect("boom")
}

pub fn sometimes(flag: bool) {
    if flag {
        panic!("fixture panic");
    }
}

pub fn later() {
    todo!()
}

pub fn never() {
    unimplemented!()
}

pub fn decoys() -> usize {
    let quiet = r#"x.unwrap() and panic!("inside a raw string")"#;
    // a comment mentioning y.expect("nothing") also stays quiet
    let fallback = Some(1).unwrap_or(0);
    quiet.len() + fallback
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        assert_eq!(Some(5).unwrap(), 5);
    }
}
