//! R3 seeds: exposition-hostile names and an unregistered bump.

pub fn register(m: &Metrics) {
    m.register_counter("bad-name", "hyphens are not prometheus-legal");
    m.register_histogram("wait_sum", "collides with generated histogram samples");
}

pub fn bump(m: &Metrics) {
    m.count("never_registered", 1);
}
