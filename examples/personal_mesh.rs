//! Personal island group + dynamic resource sharing — Scenarios 1 & 2:
//! a user's devices form a trusted mesh (laptop/mobile/TV/NAS); two hiking
//! friends rebalance inference by battery over a Bluetooth link.
//!
//! Run: `cargo run --release --example personal_mesh`

use islandrun::agents::lighthouse::Lighthouse;
use islandrun::agents::mist::Mist;
use islandrun::config::{preset_hiking_pair, preset_personal_group, Config};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::types::{IslandId, PriorityTier};
use islandrun::util::Table;

fn main() -> anyhow::Result<()> {
    // ---- Scenario 1: conversation follows the user across devices -------
    let islands = preset_personal_group();
    let lighthouse = Lighthouse::new(0x5EED, 500.0, 3);
    for i in islands.clone() {
        lighthouse.register_owned(i, 0.0);
    }
    println!("mesh registered: {} islands online", lighthouse.islands().len());

    let fleet = Fleet::new(islands.clone(), 21);
    let orch = Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 21);
    let session = orch.open_session("commuter");

    // at the desk: laptop serves
    let turn1 = orch.submit_request(
        session,
        SubmitRequest::new("refactor this helper function in the platform service").priority(PriorityTier::Secondary),
    )?;
    let t1 = islands.iter().find(|i| Some(i.id) == turn1.decision.target()).unwrap();
    println!("at the desk    -> {} (sanitized={})", t1.name, turn1.sanitized);

    // driving: the laptop leaves the mesh (lid closed — LIGHTHOUSE
    // deregisters it); the same conversation continues on another trusted
    // island without losing a request
    lighthouse.tick(10_000.0);
    orch.leave_island(IslandId(0));
    let turn2 = orch.submit_request(
        session,
        SubmitRequest::new("continue: also update the unit tests").priority(PriorityTier::Secondary),
    )?;
    let t2 = islands.iter().find(|i| Some(i.id) == turn2.decision.target()).unwrap();
    println!("in the car     -> {} (intra-group, sanitized={})", t2.name, turn2.sanitized);
    assert_ne!(t1.id, t2.id);
    assert!(!turn2.sanitized, "intra-personal-group continuation never sanitizes");

    // back home: the laptop rejoins (dynamic discovery) and serves again
    let laptop = islands.iter().find(|i| i.id == IslandId(0)).unwrap().clone();
    assert!(orch.join_island(laptop));
    let turn3 = orch.submit_request(
        session,
        SubmitRequest::new("now write the changelog entry").priority(PriorityTier::Secondary),
    )?;
    let t3 = islands.iter().find(|i| Some(i.id) == turn3.decision.target()).unwrap();
    println!("back at desk   -> {} (rejoined mesh)", t3.name);

    // ---- Scenario 2: hiking friends, battery-aware sharing --------------
    println!("\nhiking pair (battery-aware Bluetooth sharing):");
    let pair = preset_hiking_pair();
    let fleet = Fleet::new(pair.clone(), 22);
    let orch2 = Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 22);
    let s2 = orch2.open_session("friend-a");

    let mut t = Table::new("photo-enhancement requests from friend A (phone at 15% battery)", &["request", "executed on", "battery rule"]);
    for i in 0..4 {
        let out = orch2.submit_request(
            s2,
            SubmitRequest::new("enhance this mountain photo with ai").priority(PriorityTier::Secondary),
        )?;
        let island = pair.iter().find(|x| Some(x.id) == out.decision.target()).unwrap();
        t.row(&[
            format!("photo {}", i + 1),
            island.name.clone(),
            if island.id == IslandId(1) { "offloaded to friend B (90% battery)".into() } else { "local".into() },
        ]);
        orch2.advance(500.0);
    }
    t.print();
    // the low-battery phone must not serve while a charged peer exists
    let served_on_a = orch2.island_snapshot(IslandId(0)).unwrap().executed;
    let served_on_b = orch2.island_snapshot(IslandId(1)).unwrap().executed;
    println!("phone-a executed {served_on_a}, phone-b executed {served_on_b}");
    assert!(served_on_b > served_on_a, "battery-aware rebalancing must favor friend B");

    println!("\npersonal_mesh OK");
    Ok(())
}
