//! Healthcare assistant — Scenario 4 / Scenario B: a HIPAA-constrained
//! clinic serving a 1000-query day (200 high / 500 moderate / 300 low),
//! with chat-context migration across the trust boundary.
//!
//! Run: `cargo run --release --example healthcare_assistant`

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_healthcare, Config};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::healthcare_day;
use islandrun::types::{PriorityTier, TrustTier};
use islandrun::util::Table;

fn main() -> anyhow::Result<()> {
    let islands = preset_healthcare();
    let fleet = Fleet::new(islands.clone(), 4);
    let orch = Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 4);

    // ---- the 1000-query day -------------------------------------------
    let day = healthcare_day(1000, 2026);
    let session = orch.open_session("clinic");
    let mut per_tier = [0usize; 3]; // personal / edge / cloud
    let mut violations = 0usize;
    let mut cost = 0.0;
    for item in &day {
        orch.advance(86_400.0 / 1000.0 * 0.9); // spread over a virtual day
        let out =
            orch.submit_request(session, SubmitRequest::new(&item.request.prompt).priority(item.request.priority))?;
        if let Some(id) = out.decision.target() {
            let island = islands.iter().find(|i| i.id == id).unwrap();
            match island.tier {
                TrustTier::Personal => per_tier[0] += 1,
                TrustTier::PrivateEdge => per_tier[1] += 1,
                TrustTier::Cloud => per_tier[2] += 1,
            }
            if island.privacy < item.truth.score() {
                violations += 1;
            }
            cost += out.cost;
        }
    }

    let mut t = Table::new("healthcare day (Scenario 4/B)", &["metric", "value"]);
    t.row(&["queries".into(), day.len().to_string()]);
    t.row(&["on clinic workstation (PHI)".into(), per_tier[0].to_string()]);
    t.row(&["on on-prem edge (literature)".into(), per_tier[1].to_string()]);
    t.row(&["on public cloud (education)".into(), per_tier[2].to_string()]);
    t.row(&["HIPAA violations".into(), violations.to_string()]);
    t.row(&["cloud spend".into(), format!("${cost:.2}")]);
    t.print();
    assert_eq!(violations, 0, "PHI must never reach a low-privacy island");

    // ---- context migration demo (§VII.B) -------------------------------
    println!("context migration across the trust boundary:");
    let s = orch.open_session("dr-lee");
    let turn1 = orch.submit_request(
        s,
        SubmitRequest::new("patient john doe ssn 123-45-6789 diagnosed with diabetes, hba1c elevated")
            .priority(PriorityTier::Primary),
    )?;
    println!("  turn 1 (PHI): s_r={:.2} -> {:?}, sanitized={}", turn1.s_r, turn1.decision.target(), turn1.sanitized);

    // saturate the clinic + edge so the general follow-up must use cloud
    orch.saturate_bounded_islands(0.99);
    let turn2 = orch.submit_request(
        s,
        SubmitRequest::new("what lifestyle changes are usually recommended").priority(PriorityTier::Burstable),
    )?;
    let island = islands.iter().find(|i| Some(i.id) == turn2.decision.target()).unwrap();
    println!(
        "  turn 2 (general): s_r={:.2} -> {} (P={}), history sanitized={}",
        turn2.s_r, island.name, island.privacy, turn2.sanitized
    );
    assert!(turn2.sanitized, "crossing the trust boundary must sanitize chat history");

    // show what the cloud actually saw
    let leaked = orch
        .sessions
        .with_mut(s, |sess| {
            sess.placeholders.sanitize("patient john doe ssn 123-45-6789 diagnosed with diabetes", island.privacy)
        })
        .unwrap();
    println!("  cloud-visible history example: \"{leaked}\"");
    assert!(!leaked.contains("john doe") && !leaked.contains("123-45-6789"));

    println!("\nhealthcare_assistant OK");
    Ok(())
}
