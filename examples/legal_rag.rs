//! Legal-firm RAG — Scenario 3/C: the case-law vector store lives on the
//! firm server; IslandRun routes *compute to data*. Uses the real AOT
//! Embedder artifact when available (falls back to the rust featurizer +
//! random projection otherwise, so the example always runs).
//!
//! Run: `cargo run --release --example legal_rag`

use std::path::Path;

use islandrun::agents::waves::Waves;
use islandrun::agents::tide::hysteresis::Preference;
use islandrun::config::{preset_legal, Config};
use islandrun::islands::Fleet;
use islandrun::runtime::{features, Engine};
use islandrun::substrate::trace::rag_trace;
use islandrun::substrate::vectorstore::VectorStore;
use islandrun::util::Table;

const CASE_LAW: &[&str] = &[
    "contract dispute over delivery timelines in maritime shipping",
    "precedent on data privacy obligations for cloud storage providers",
    "employment agreement non-compete clause enforceability ruling",
    "patent infringement claim regarding distributed routing algorithms",
    "liability for autonomous vehicle sensor failures on highways",
    "medical malpractice standard of care for remote diagnosis",
    "intellectual property assignment in open source contributions",
    "negligence claim for inadequate network security controls",
    "arbitration clause enforceability in consumer software licenses",
    "regulatory compliance for cross border financial data transfers",
    "trade secret misappropriation by departing employees",
    "class action over misleading subscription renewal practices",
];

fn embed(engine: Option<&Engine>, texts: &[String]) -> anyhow::Result<Vec<Vec<f32>>> {
    match engine {
        Some(e) => e.handle().embed(texts.to_vec()),
        None => {
            // deterministic fallback: featurizer + fixed projection via FNV
            Ok(texts
                .iter()
                .map(|t| {
                    let f = features::featurize(t);
                    let mut out = vec![0f32; 64];
                    for (i, &v) in f.iter().enumerate() {
                        out[i % 64] += v * if (features::fnv1a(&[i as u8]) & 1) == 0 { 1.0 } else { -1.0 };
                    }
                    let n: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                    out.iter().map(|x| x / n).collect()
                })
                .collect())
        }
    }
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::load(Path::new("artifacts")).ok();
    if engine.is_none() {
        println!("(artifacts not built — using fallback embedder; run `make artifacts` for the real one)");
    }

    // 1) Build the firm's vector store (lives ON the firm server island)
    let texts: Vec<String> = CASE_LAW.iter().map(|s| s.to_string()).collect();
    let embs = embed(engine.as_ref(), &texts)?;
    let mut store = VectorStore::new(embs[0].len());
    for (i, (text, e)) in texts.iter().zip(embs).enumerate() {
        store.insert(i as u64, text, e)?;
    }
    let store_path = std::env::temp_dir().join("islandrun_case_law.json");
    store.save(&store_path)?;
    println!("firm vector store: {} docs, {:.1} KB on disk, saved to {}", store.len(), store.payload_kb(), store_path.display());

    // 2) Route queries: data-locality forces the firm server
    let islands = preset_legal();
    let fleet = Fleet::new(islands.clone(), 12);
    let waves = Waves::new(Config::default());
    let queries = rag_trace(6, "case_law", 3);

    let mut t = Table::new("compute-to-data routing (Scenario 3/C)", &["query", "routed to", "top case-law hit"]);
    for item in &queries {
        let states = fleet.states();
        let d = waves.route(&item.request, 0.8, &states, fleet.local_capacity(), Preference::Local, f64::INFINITY);
        let target = islands.iter().find(|i| Some(i.id) == d.target()).expect("routable");
        assert_eq!(target.name, "firm-server", "data locality must win");
        // run retrieval where the data lives
        let qe = embed(engine.as_ref(), &[item.request.prompt.clone()])?;
        let hits = store.search(&qe[0], 1);
        let best = store.get(hits[0].id).unwrap();
        t.row(&[
            item.request.prompt.chars().take(44).collect::<String>(),
            target.name.clone(),
            best.text.chars().take(44).collect::<String>(),
        ]);
    }
    t.print();

    // 3) The counterfactual: uploading the corpus to cloud per query
    let corpus_kb = store.payload_kb();
    println!(
        "bytes moved per query — compute-to-data: ~{:.1} KB (query only) vs data-to-compute: ~{:.1} KB (corpus shard)",
        0.5,
        corpus_kb
    );
    std::fs::remove_file(&store_path).ok();
    println!("\nlegal_rag OK");
    Ok(())
}
