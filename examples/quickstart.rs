//! Quickstart — the END-TO-END driver: load the real AOT TinyLM artifacts,
//! stand up a personal-island-group mesh, and serve a batched
//! mixed-sensitivity workload through the full Fig. 2 pipeline
//! (MIST → TIDE → WAVES → island execute → desanitize), reporting
//! latency / throughput / cost / privacy. Results are recorded in
//! EXPERIMENTS.md §E13.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::Path;
use std::time::Instant;

use islandrun::agents::mist::{Mist, Stage2};
use islandrun::config::{preset_personal_group, Config};
use islandrun::islands::executor::IslandExecutor;
use islandrun::runtime::{BatchPolicy, Batcher, Engine};
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::{paper_mix, SensClass};
use islandrun::util::Table;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    anyhow::ensure!(artifacts.join("meta.json").exists(), "run `make artifacts` first");

    println!("loading AOT artifacts (HLO text -> PJRT)…");
    let engine = Engine::load(artifacts)?;
    let meta = engine.meta().clone();
    println!(
        "  TinyLM seq_len {}, vocab {}, batch variants {:?}; classifier val acc {:.3}",
        meta.seq_len, meta.vocab, meta.lm_batch_variants, meta.classifier_val_acc
    );
    println!("  LM training loss curve (from meta.json): {:?}", meta.lm_loss_curve);

    // 1) The full orchestrated pipeline over the REAL engine --------------
    let mist = Mist::new(Stage2::Classifier(engine.handle()));
    let executor = IslandExecutor::new(engine.handle(), 7);
    let islands = preset_personal_group();
    let orch = Orchestrator::new(Config::default(), mist, Backend::Real { executor, islands: islands.clone() }, 7);
    let session = orch.open_session("quickstart");

    let n = 48;
    let trace = paper_mix(n, 42);
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut violations = 0usize;
    let mut total_cost = 0.0;
    let mut latencies = Vec::new();
    for item in &trace {
        let out =
            orch.submit_request(session, SubmitRequest::new(&item.request.prompt).priority(item.request.priority))?;
        if let Some(id) = out.decision.target() {
            let island = islands.iter().find(|i| i.id == id).unwrap();
            if island.privacy < item.truth.score() {
                violations += 1;
            }
            served += 1;
            latencies.push(out.latency_ms);
            total_cost += out.cost;
            if served <= 6 {
                println!(
                    "  [{}] s_r={:.2} -> {:<16} {:>7.1}ms  \"{}…\"",
                    served,
                    out.s_r,
                    island.name,
                    out.latency_ms,
                    &out.response[..out.response.len().min(28)]
                );
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // 2) Dynamic batching throughput on the raw engine --------------------
    let mut batcher = Batcher::new(BatchPolicy::default());
    for item in trace.iter().take(24) {
        batcher.push(item.request.prompt.clone());
    }
    let tb = Instant::now();
    let mut batched_tokens = 0usize;
    while !batcher.is_empty() {
        let batch = batcher.take_batch();
        let gens = engine.handle().generate(batch, 8)?;
        batched_tokens += gens.iter().map(|g| g.tokens_generated).sum::<usize>();
    }
    let batch_wall = tb.elapsed().as_secs_f64();

    let mut t = Table::new("quickstart end-to-end (E13)", &["metric", "value"]);
    t.row(&["requests served".into(), format!("{served}/{n}")]);
    t.row(&["wall time".into(), format!("{wall:.2}s")]);
    t.row(&["throughput".into(), format!("{:.2} req/s", served as f64 / wall)]);
    t.row(&["p50 latency".into(), format!("{:.1} ms", islandrun::util::stats::percentile(&latencies, 0.5))]);
    t.row(&["p95 latency".into(), format!("{:.1} ms", islandrun::util::stats::percentile(&latencies, 0.95))]);
    t.row(&["privacy violations (ground truth)".into(), violations.to_string()]);
    t.row(&["total cost".into(), format!("${total_cost:.4}")]);
    t.row(&[
        "batched decode".into(),
        format!("{batched_tokens} tokens in {batch_wall:.2}s ({:.1} tok/s)", batched_tokens as f64 / batch_wall),
    ]);
    t.print();

    let high = trace.iter().filter(|i| i.truth == SensClass::High).count();
    println!(
        "workload mix: {high} high / {} moderate / {} low",
        trace.iter().filter(|i| i.truth == SensClass::Moderate).count(),
        trace.iter().filter(|i| i.truth == SensClass::Low).count()
    );
    assert_eq!(violations, 0, "IslandRun must never violate privacy");
    println!("\nquickstart OK");
    Ok(())
}
