//! Red-team drill — runs all five §VIII.C attack scenarios against the live
//! components and verifies every mitigation holds.
//!
//! Run: `cargo run --release --example attack_drill`

use islandrun::security;

fn main() {
    let outcomes = security::run_all();
    let mut failed = 0;
    println!("§VIII.C attack drill:");
    for o in &outcomes {
        println!("  {:<28} mitigated={:<5} {}", o.name, o.mitigated, o.details);
        if !o.mitigated {
            failed += 1;
        }
    }
    if failed > 0 {
        eprintln!("\n{failed} attack(s) NOT mitigated");
        std::process::exit(1);
    }
    println!("\nall {} attacks mitigated — attack_drill OK", outcomes.len());
}
